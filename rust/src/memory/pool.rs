//! Persistent worker pool + scratch arenas — the orchestration layer
//! under every fan-out in the system.
//!
//! PR 3's tile engine made clean decode/scrub nearly free *per byte*,
//! which left pure orchestration as the dominant steady-state cost:
//! every scrub tick, decode pass and campaign cell used to spawn and
//! join fresh OS threads through `std::thread::scope`. This module
//! replaces that with one process-wide pool of long-lived parked
//! workers:
//!
//! * **Queues** — a shared injector (external submissions) plus one
//!   stealable run queue per worker. A worker prefers its own queue
//!   (LIFO: nested work stays hot), then the injector, then steals the
//!   *back* of a sibling's queue. A task submitted from inside a pool
//!   worker lands on that worker's own queue, so nested fan-outs
//!   (campaign cell → trial → shard decode) pipeline instead of
//!   serializing behind a barrier.
//! * **`Pool::run`** — a `scope`-style borrow API: jobs may capture
//!   `&mut` windows of caller-stack buffers exactly like
//!   `std::thread::scope` spawns did. Internally the borrows are
//!   lifetime-erased and handed to the workers as *tickets*; `run`
//!   blocks on a heap-allocated latch until every ticket retires, so
//!   the borrows can never outlive the call. The caller participates
//!   (it drains its own job queue), and before parking it *reclaims*
//!   any of its tickets still sitting unstarted in the queues — after
//!   that, every awaited ticket is running on some worker, so nested
//!   `run` calls are deadlock-free even on a one-thread pool. A
//!   waiting caller never executes another frame's work, so a job that
//!   holds a lock (e.g. a campaign trial holding its model's
//!   `EvalCtx` mutex) can never re-enter itself on the same thread.
//! * **Panic propagation** — a panicking job poisons nothing: the first
//!   panic payload is captured, remaining jobs are abandoned, and the
//!   payload is re-raised on the calling thread after every ticket has
//!   retired (same observable behavior as a scoped join).
//! * **Scratch arenas** — per-worker (thread-local) freelists of
//!   recycled `Vec<i8>` / `Vec<f32>` buffers: [`lease_i8`] /
//!   [`lease_f32`] hand one out, dropping the [`Scratch`] returns it,
//!   [`Scratch::take`] detaches the buffer (e.g. to cross a channel)
//!   and [`give`] re-parks it. [`arena_stats`] counts hits vs fresh
//!   allocations — the bench's steady-state allocations-per-scrub-tick
//!   gauge.
//!
//! [`run_jobs`] is the compatibility wrapper every pre-pool call site
//! keeps using; it delegates to the global pool. [`run_jobs_scoped`]
//! preserves the old scoped-spawn fan-out as the reference
//! implementation the equivalence proptests and the `ecc_hotpath`
//! `pool` bench section compare against.
//!
//! Lifecycle: the global pool ([`Pool::global`]) is created on first
//! use, sized `min(available_parallelism, 8)`, and lives for the
//! process. Private pools (`Pool::new`) are for tests; `shutdown` is
//! idempotent, and `run` on a shut-down pool still completes — the
//! caller reclaims its own tickets.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::{JoinHandle, LocalKey};

/// A lifetime-erased unit of pool work (see the safety argument in
/// [`Pool::run`]).
type Task = Box<dyn FnOnce() + Send>;

/// A queued task tagged with the identity of the `run` frame that
/// submitted it (the latch address), so a waiting caller can reclaim
/// its own unstarted tickets.
type Entry = (usize, Task);

struct Queues {
    /// External submissions (callers that are not pool workers).
    injector: VecDeque<Entry>,
    /// Per-worker run queues: owner pops the front, thieves the back.
    locals: Vec<VecDeque<Entry>>,
    shutdown: bool,
}

/// A persistent pool of parked worker threads.
pub struct Pool {
    q: Mutex<Queues>,
    work_cv: Condvar,
    threads: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

thread_local! {
    /// (pool identity, worker index) when this thread is a pool worker.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

static GLOBAL: OnceLock<Arc<Pool>> = OnceLock::new();

impl Pool {
    /// Spawn a pool of `threads` parked workers (clamped to >= 1).
    pub fn new(threads: usize) -> Arc<Pool> {
        let threads = threads.max(1);
        let pool = Arc::new(Pool {
            q: Mutex::new(Queues {
                injector: VecDeque::new(),
                locals: (0..threads).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            threads,
            handles: Mutex::new(Vec::new()),
        });
        let mut handles = pool.handles.lock().unwrap();
        for i in 0..threads {
            let p = pool.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("zsecc-pool-{i}"))
                    .spawn(move || worker_loop(p, i))
                    .expect("spawning pool worker"),
            );
        }
        drop(handles);
        pool
    }

    /// The process-wide shared pool: `ShardedBank` passes, campaign
    /// cells/trials and the serving scrub loop all fan out here.
    pub fn global() -> &'static Arc<Pool> {
        GLOBAL.get_or_init(|| Pool::new(Pool::default_threads()))
    }

    /// Pool size for this machine (capped: the workloads are
    /// memory-bound well before they are core-bound).
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .clamp(1, 8)
    }

    /// Worker threads in this pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn id(&self) -> usize {
        self as *const Pool as usize
    }

    /// Park all workers and join them. Idempotent; queued work is
    /// drained before a worker exits, and a later `run` still completes
    /// (the caller reclaims its own tickets).
    pub fn shutdown(&self) {
        {
            let mut q = self.q.lock().unwrap();
            q.shutdown = true;
        }
        self.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }

    /// Enqueue a task for frame `fid`: a pool worker pushes to its own
    /// (stealable) run queue, everyone else to the shared injector.
    fn submit(&self, fid: usize, task: Task) {
        {
            let mut q = self.q.lock().unwrap();
            match WORKER.get() {
                Some((id, idx)) if id == self.id() => q.locals[idx].push_front((fid, task)),
                _ => q.injector.push_back((fid, task)),
            }
        }
        self.work_cv.notify_one();
    }

    /// Remove (and drop) every still-queued ticket of frame `fid`,
    /// returning how many were removed. After this, all of the frame's
    /// unretired tickets are *running* on some worker — the waiting
    /// caller can park without executing anyone else's work.
    fn reclaim(&self, fid: usize) -> usize {
        let mut q = self.q.lock().unwrap();
        let mut removed = 0;
        let before = q.injector.len();
        q.injector.retain(|(id, _)| *id != fid);
        removed += before - q.injector.len();
        for local in q.locals.iter_mut() {
            let before = local.len();
            local.retain(|(id, _)| *id != fid);
            removed += before - local.len();
        }
        removed
    }

    /// Run `jobs` through `f` on at most `workers` threads (the caller
    /// counts as one), returning results in job submission order.
    /// Serial on the calling thread when one worker or one job. Jobs
    /// may borrow from the caller's stack (`&mut` buffer windows
    /// included) — `run` does not return until every borrow is dead.
    /// A panicking job abandons the remaining jobs and re-raises on the
    /// caller once all workers have let go.
    pub fn run<J, R, F>(&self, jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
    where
        J: Send,
        R: Send,
        F: Fn(J) -> R + Sync,
    {
        let n = jobs.len();
        if workers <= 1 || n <= 1 {
            return jobs.into_iter().map(f).collect();
        }
        let frame = RunFrame {
            queue: Mutex::new(jobs.into_iter().enumerate().collect()),
            results: Mutex::new((0..n).map(|_| None).collect()),
            panic: Mutex::new(None),
            f,
        };
        // The caller participates, so tickets = extra workers only.
        let tickets = workers.min(self.threads + 1).saturating_sub(1).min(n - 1);
        let latch = Latch::new(tickets);
        let fid = Arc::as_ptr(&latch) as usize; // unique while the latch lives
        let fp = SendPtr(&frame as *const RunFrame<J, R, F>);
        for _ in 0..tickets {
            let latch = latch.clone();
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                // SAFETY: the frame outlives the ticket — `run` blocks
                // on the latch below until this retire() has happened,
                // and retire touches only the Arc'd latch, never the
                // frame.
                unsafe { (*fp.0).drain() };
                latch.retire();
            });
            // SAFETY: erasing the borrow of `frame` (and whatever `f`
            // captures) to 'static is sound because `run` cannot return
            // before the latch confirms every ticket has finished: the
            // borrows are dead by the time the frame is dropped.
            self.submit(fid, unsafe { erase_task(task) });
        }
        frame.drain(); // the caller is a worker too
        // Our queue is dry: tickets still waiting in the pool queues
        // have nothing left to do — pull them back out instead of
        // waiting for a worker to start them. Whatever remains is
        // running right now and will retire on its own.
        latch.retire_n(self.reclaim(fid));
        latch.wait();
        if let Some(payload) = frame.panic.into_inner().unwrap() {
            resume_unwind(payload);
        }
        frame
            .results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("pool job completed without a result"))
            .collect()
    }
}

fn worker_loop(pool: Arc<Pool>, idx: usize) {
    WORKER.set(Some((pool.id(), idx)));
    loop {
        let task = {
            let mut q = pool.q.lock().unwrap();
            loop {
                if let Some(t) = next_task(&mut q, idx) {
                    break Some(t);
                }
                if q.shutdown {
                    break None;
                }
                q = pool.work_cv.wait(q).unwrap();
            }
        };
        match task {
            // Tickets catch job panics internally; the outer catch only
            // guards the worker thread against future task kinds.
            Some((_fid, t)) => drop(catch_unwind(AssertUnwindSafe(t))),
            None => return,
        }
    }
}

fn next_task(q: &mut Queues, idx: usize) -> Option<Entry> {
    if let Some(t) = q.locals[idx].pop_front() {
        return Some(t);
    }
    if let Some(t) = q.injector.pop_front() {
        return Some(t);
    }
    let n = q.locals.len();
    for off in 1..n {
        if let Some(t) = q.locals[(idx + off) % n].pop_back() {
            return Some(t);
        }
    }
    None
}

/// Shared state of one `run` call, on the caller's stack.
struct RunFrame<J, R, F> {
    queue: Mutex<VecDeque<(usize, J)>>,
    results: Mutex<Vec<Option<R>>>,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    f: F,
}

impl<J, R, F: Fn(J) -> R> RunFrame<J, R, F> {
    /// Pull jobs until the queue is dry (or a sibling panicked).
    fn drain(&self) {
        loop {
            if self.panic.lock().unwrap().is_some() {
                return; // abandon the rest; `run` re-raises
            }
            let Some((idx, job)) = self.queue.lock().unwrap().pop_front() else {
                return;
            };
            match catch_unwind(AssertUnwindSafe(|| (self.f)(job))) {
                Ok(r) => self.results.lock().unwrap()[idx] = Some(r),
                Err(payload) => {
                    let mut slot = self.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload); // first panic wins
                    }
                }
            }
        }
    }
}

/// Erase a task's borrow lifetime so it can sit in the 'static queues.
///
/// SAFETY: the caller must guarantee the task runs (and its borrows
/// die) before the erased lifetime ends — `Pool::run` enforces this
/// with the ticket latch.
unsafe fn erase_task<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Task>(task)
}

/// Raw frame pointer that crosses into tickets.
///
/// SAFETY: only constructed in [`Pool::run`], whose bounds (`J: Send`,
/// `R: Send`, `F: Sync`) make sharing the frame across threads sound;
/// the latch protocol bounds its lifetime.
struct SendPtr<T>(*const T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Heap-allocated completion latch: one count per ticket.
struct Latch {
    left: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Arc<Latch> {
        Arc::new(Latch {
            left: Mutex::new(n),
            cv: Condvar::new(),
        })
    }

    fn retire(&self) {
        self.retire_n(1);
    }

    /// Retire `n` tickets at once (the reclaimed, never-started ones).
    fn retire_n(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut left = self.left.lock().unwrap();
        *left -= n;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every ticket retires. Safe to call only after the
    /// caller reclaimed its queued tickets: everything still counted is
    /// running on a worker, executing this frame's own jobs — never a
    /// wait on work nobody has started, and never a foreign job run on
    /// this thread (which could re-enter a lock the caller holds).
    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left != 0 {
            left = self.cv.wait(left).unwrap();
        }
    }
}

// ------------------------------------------------- compat + reference --

/// Fan `jobs` out over at most `workers` threads of the global
/// persistent pool; returns results in job submission order. Serial on
/// the calling thread when one worker or one job. This is the
/// compatibility wrapper every pre-pool call site keeps using — shard
/// scrub/decode passes, the campaign engine's cells and trials, and
/// the serving scrub loop all funnel through it.
pub fn run_jobs<J, R>(jobs: Vec<J>, workers: usize, f: impl Fn(J) -> R + Sync) -> Vec<R>
where
    J: Send,
    R: Send,
{
    Pool::global().run(jobs, workers, f)
}

/// The pre-pool scoped-spawn fan-out (round-robin buckets over fresh
/// `std::thread::scope` threads), kept as the reference implementation
/// the pool-equivalence proptests and the `ecc_hotpath` `pool` bench
/// section compare against. Returns results in bucket order.
pub fn run_jobs_scoped<J, R>(jobs: Vec<J>, workers: usize, f: impl Fn(J) -> R + Sync) -> Vec<R>
where
    J: Send,
    R: Send,
{
    if workers <= 1 || jobs.len() <= 1 {
        return jobs.into_iter().map(f).collect();
    }
    let nw = workers.min(jobs.len());
    let mut buckets: Vec<Vec<J>> = (0..nw).map(|_| Vec::new()).collect();
    for (k, job) in jobs.into_iter().enumerate() {
        buckets[k % nw].push(job);
    }
    let f = &f;
    let mut results = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| scope.spawn(move || bucket.into_iter().map(f).collect::<Vec<R>>()))
            .collect();
        for h in handles {
            results.extend(h.join().expect("scoped worker panicked"));
        }
    });
    results
}

// ------------------------------------------------------ scratch arenas --

/// Recycled buffers ever handed out (freelist hits).
static ARENA_HITS: AtomicU64 = AtomicU64::new(0);
/// Leases that had to allocate (empty freelist or too-small buffer).
static ARENA_MISSES: AtomicU64 = AtomicU64::new(0);

/// Per-thread freelist depth cap — bounds idle memory, not throughput.
/// Must cover the worst-case buffers parked on one thread per serving
/// epoch: a delta refresh returns up to (shards - 1) f32 buffers to
/// the scrub thread, and 64-shard stores are the common large config.
const MAX_FREE_PER_THREAD: usize = 128;

thread_local! {
    static FREE_I8: RefCell<Vec<Vec<i8>>> = const { RefCell::new(Vec::new()) };
    static FREE_F32: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Element types the arena recycles buffers of.
pub trait ArenaElem: Copy + Default + 'static {
    #[doc(hidden)]
    fn freelist() -> &'static LocalKey<RefCell<Vec<Vec<Self>>>>;
}

impl ArenaElem for i8 {
    fn freelist() -> &'static LocalKey<RefCell<Vec<Vec<i8>>>> {
        &FREE_I8
    }
}

impl ArenaElem for f32 {
    fn freelist() -> &'static LocalKey<RefCell<Vec<Vec<f32>>>> {
        &FREE_F32
    }
}

/// A leased arena buffer: derefs to its `Vec`, returns to the leasing
/// thread's freelist on drop. [`Scratch::take`] detaches the buffer
/// instead (hand it back later with [`give`]).
pub struct Scratch<T: ArenaElem> {
    buf: Vec<T>,
}

impl<T: ArenaElem> Scratch<T> {
    /// Detach the buffer from the arena, e.g. to move it into a channel
    /// message; the receiver returns it with [`give`].
    pub fn take(mut self) -> Vec<T> {
        std::mem::take(&mut self.buf)
    }
}

impl<T: ArenaElem> std::ops::Deref for Scratch<T> {
    type Target = Vec<T>;
    fn deref(&self) -> &Vec<T> {
        &self.buf
    }
}

impl<T: ArenaElem> std::ops::DerefMut for Scratch<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.buf
    }
}

impl<T: ArenaElem> Drop for Scratch<T> {
    fn drop(&mut self) {
        give(std::mem::take(&mut self.buf));
    }
}

/// Lease a zero-filled buffer of `len` elements from this thread's
/// freelist (allocating only when nothing big enough is parked there).
pub fn lease<T: ArenaElem>(len: usize) -> Scratch<T> {
    let recycled = T::freelist().with(|fl| fl.borrow_mut().pop());
    let mut buf = match recycled {
        Some(b) if b.capacity() >= len => {
            ARENA_HITS.fetch_add(1, Ordering::Relaxed);
            b
        }
        Some(b) => {
            // too small: the resize below reallocates
            ARENA_MISSES.fetch_add(1, Ordering::Relaxed);
            b
        }
        None => {
            ARENA_MISSES.fetch_add(1, Ordering::Relaxed);
            Vec::new()
        }
    };
    buf.clear();
    buf.resize(len, T::default());
    Scratch { buf }
}

/// [`lease`] for the decode scratch (`Vec<i8>`) buffers.
pub fn lease_i8(len: usize) -> Scratch<i8> {
    lease(len)
}

/// [`lease`] for the dequantized-weight (`Vec<f32>`) buffers.
pub fn lease_f32(len: usize) -> Scratch<f32> {
    lease(len)
}

/// Park a buffer in this thread's freelist (e.g. a delta buffer the
/// inference thread has applied and shipped back).
pub fn give<T: ArenaElem>(buf: Vec<T>) {
    if buf.capacity() == 0 {
        return;
    }
    T::freelist().with(|fl| {
        let mut fl = fl.borrow_mut();
        if fl.len() < MAX_FREE_PER_THREAD {
            fl.push(buf);
        }
    });
}

/// `(hits, misses)` across all threads since process start: `misses`
/// counts leases that allocated — the bench's steady-state
/// allocations-per-scrub-tick gauge reads its delta.
pub fn arena_stats() -> (u64, u64) {
    (
        ARENA_HITS.load(Ordering::Relaxed),
        ARENA_MISSES.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_submission_order() {
        let pool = Pool::new(4);
        let out = pool.run((0..200).collect::<Vec<usize>>(), 8, |i| i * 3);
        assert_eq!(out, (0..200).map(|i| i * 3).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn pool_matches_scoped_reference() {
        let pool = Pool::new(3);
        let jobs: Vec<(usize, u64)> = (0..57).map(|i| (i, i as u64 * 0x9E37)).collect();
        let f = |(i, x): (usize, u64)| (i, x.rotate_left(7) ^ 0xABCD);
        for workers in [1usize, 2, 7, 16] {
            let mut a = pool.run(jobs.clone(), workers, f);
            let mut b = run_jobs_scoped(jobs.clone(), workers, f);
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "{workers} workers");
        }
        pool.shutdown();
    }

    #[test]
    fn scope_style_borrowed_windows() {
        // the port surface: jobs hold &mut windows of a caller buffer
        let pool = Pool::new(3);
        let mut buf = vec![0u32; 1000];
        let jobs: Vec<(usize, &mut [u32])> = buf.chunks_mut(100).enumerate().collect();
        let out = pool.run(jobs, 4, |(i, win)| {
            for (k, v) in win.iter_mut().enumerate() {
                *v = (i * 100 + k) as u32;
            }
            i
        });
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        assert!(buf.iter().enumerate().all(|(k, &v)| v == k as u32));
        pool.shutdown();
    }

    #[test]
    fn nested_runs_on_a_small_pool_complete() {
        // deadlock-freedom: 6 outer jobs each fan out 5 inner jobs on a
        // 2-thread pool; caller participation + helping must drain it
        let pool = Pool::new(2);
        let outer = pool.run((0..6u64).collect::<Vec<_>>(), 4, |i| {
            pool.run((0..5u64).collect::<Vec<_>>(), 4, |j| i * 10 + j)
                .iter()
                .sum::<u64>()
        });
        let mut want = Vec::new();
        for i in 0..6u64 {
            want.push((0..5).map(|j| i * 10 + j).sum::<u64>());
        }
        assert_eq!(outer, want);
        pool.shutdown();
    }

    #[test]
    fn job_panics_propagate_and_pool_survives() {
        let pool = Pool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run((0..8).collect::<Vec<i32>>(), 4, |j| {
                if j == 5 {
                    panic!("job {j} exploded");
                }
                j
            })
        }));
        assert!(r.is_err(), "job panic must reach the caller");
        // the pool is intact: workers alive, next run clean
        assert_eq!(pool.run(vec![1, 2, 3], 4, |x| x * 2), vec![2, 4, 6]);
        pool.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_run_degrades_gracefully() {
        let pool = Pool::new(2);
        assert_eq!(pool.run(vec![1, 2, 3], 4, |x| x + 1), vec![2, 3, 4]);
        pool.shutdown();
        pool.shutdown(); // second shutdown must not hang or panic
        // tickets queued on a dead pool are reclaimed by the caller
        assert_eq!(pool.run(vec![1, 2, 3], 4, |x| x * 2), vec![2, 4, 6]);
    }

    #[test]
    fn global_run_jobs_smoke() {
        let out = run_jobs((0..40).collect::<Vec<usize>>(), 4, |i| i + 1);
        assert_eq!(out, (1..41).collect::<Vec<_>>());
        // serial fast paths
        assert_eq!(run_jobs(vec![7], 8, |x: i32| x), vec![7]);
        assert_eq!(run_jobs(vec![1, 2], 1, |x: i32| x), vec![1, 2]);
    }

    #[test]
    fn arena_recycles_buffers() {
        // thread-local freelists: this sequence is deterministic even
        // with other tests leasing on other threads (stats are global,
        // so compare deltas only)
        let big = 1 << 20;
        drop(lease_i8(big)); // allocates, then parks in the freelist
        let (h0, _) = arena_stats();
        let b = lease_i8(big); // must recycle the parked buffer
        let (h1, _) = arena_stats();
        assert!(h1 > h0, "re-lease must hit the freelist");
        assert!(b.capacity() >= big);
        assert!(b.iter().all(|&x| x == 0), "leases are zero-filled");
        let v = b.take(); // detach (the channel-crossing path)
        give(v); // hand it back
        let (h1, _) = arena_stats();
        let c = lease_f32(64);
        drop(c);
        let (h2, m2) = arena_stats();
        drop(lease_f32(64));
        let (h3, m3) = arena_stats();
        assert!(h3 > h2 || m3 == m2, "f32 freelist must recycle too");
        let _ = h1;
    }
}
