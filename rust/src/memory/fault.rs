//! Fault models.
//!
//! Paper section 5.3: "The fault model is random bit flip. ... The
//! number of faulty bits is the product of the number of bits used to
//! represent weights of a CNN and the memory fault rate." We implement
//! that exactly: `n_flips = round(rate * total_bits)` *distinct* bit
//! positions drawn uniformly over the stored image (data + out-of-band
//! check storage — a scheme's own redundancy is equally exposed).
//!
//! The burst model (ablation, not in the paper) flips runs of adjacent
//! bits — the failure signature of multi-cell upsets — to probe where
//! SEC-DED's single-error assumption breaks down.

use crate::ecc::Encoded;
use crate::util::rng::Rng;

/// Fault model selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultModel {
    /// Independent uniform bit flips (the paper's model).
    Uniform,
    /// Bursts of `len` adjacent flipped bits; the *total* flipped-bit
    /// budget still follows the rate (n_bursts = n_flips / len).
    Burst { len: u32 },
}

/// Deterministic fault injector.
pub struct FaultInjector {
    pub model: FaultModel,
    rng: Rng,
}

impl FaultInjector {
    pub fn new(model: FaultModel, seed: u64) -> Self {
        FaultInjector {
            model,
            rng: Rng::new(seed),
        }
    }

    /// Number of faulty bits for a stored image at `rate` (paper
    /// semantics; rounds to nearest).
    pub fn flip_count(total_bits: u64, rate: f64) -> u64 {
        (total_bits as f64 * rate).round() as u64
    }

    /// Inject faults at `rate` into the image; returns bits flipped.
    pub fn inject(&mut self, enc: &mut Encoded, rate: f64) -> u64 {
        let total = enc.total_bits();
        let n = Self::flip_count(total, rate);
        self.inject_count(enc, n)
    }

    /// Inject exactly `n` flipped bits (distinct positions).
    pub fn inject_count(&mut self, enc: &mut Encoded, n: u64) -> u64 {
        let positions = self.draw_positions(enc.total_bits(), n);
        let flipped = positions.len() as u64;
        for pos in positions {
            enc.flip_bit(pos);
        }
        flipped
    }

    /// Draw the bit positions an `inject_count` call would flip, without
    /// flipping them — the sharded bank uses this to both flip and mark
    /// the shards the faults land in. For a given (model, seed) the
    /// sequence is identical to what `inject`/`inject_count` consume.
    pub fn draw_positions(&mut self, total_bits: u64, n: u64) -> Vec<u64> {
        match self.model {
            FaultModel::Uniform => {
                let n = n.min(total_bits);
                self.rng.distinct(total_bits, n)
            }
            FaultModel::Burst { len } => {
                let len = len.max(1) as u64;
                let bursts = n / len;
                let mut positions = Vec::with_capacity((bursts * len) as usize);
                for _ in 0..bursts {
                    let start = self.rng.below(total_bits);
                    for k in 0..len {
                        // bursts wrap within the image, stay distinct per burst
                        positions.push((start + k) % total_bits);
                    }
                }
                positions
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image(nbytes: usize) -> Encoded {
        Encoded {
            data: vec![0u8; nbytes],
            oob: vec![0u8; nbytes / 8],
            n: nbytes,
        }
    }

    #[test]
    fn count_semantics_match_paper() {
        // 1e6 weight bits at 1e-3 -> exactly 1000 flips.
        assert_eq!(FaultInjector::flip_count(1_000_000, 1e-3), 1000);
        // sub-one expectation rounds: 1e4 bits at 1e-5 -> 0 flips.
        assert_eq!(FaultInjector::flip_count(10_000, 1e-5), 0);
        assert_eq!(FaultInjector::flip_count(10_000, 6e-5), 1);
    }

    #[test]
    fn uniform_flips_exact_distinct_count() {
        let mut enc = image(1024);
        let mut inj = FaultInjector::new(FaultModel::Uniform, 42);
        let n = inj.inject(&mut enc, 1e-2); // 1024*8*1.125 bits * 1e-2 ≈ 92
        let ones: u32 = enc
            .data
            .iter()
            .chain(&enc.oob)
            .map(|b| b.count_ones())
            .sum();
        assert_eq!(ones as u64, n, "flips must hit distinct bits");
    }

    #[test]
    fn oob_bits_are_exposed_too() {
        let mut hit_oob = false;
        for seed in 0..50 {
            let mut enc = image(64);
            let mut inj = FaultInjector::new(FaultModel::Uniform, seed);
            inj.inject_count(&mut enc, 40);
            if enc.oob.iter().any(|&b| b != 0) {
                hit_oob = true;
                break;
            }
        }
        assert!(hit_oob, "faults must be able to land in check storage");
    }

    #[test]
    fn burst_flips_adjacent() {
        let mut enc = image(1024);
        let mut inj = FaultInjector::new(FaultModel::Burst { len: 4 }, 7);
        let flipped = inj.inject_count(&mut enc, 8);
        assert_eq!(flipped, 8); // two bursts of 4
        let ones: u32 = enc
            .data
            .iter()
            .chain(&enc.oob)
            .map(|b| b.count_ones())
            .sum();
        assert!(ones <= 8 && ones >= 5, "bursts may self-overlap only rarely");
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = image(256);
        let mut b = image(256);
        FaultInjector::new(FaultModel::Uniform, 99).inject_count(&mut a, 50);
        FaultInjector::new(FaultModel::Uniform, 99).inject_count(&mut b, 50);
        assert_eq!(a.data, b.data);
        assert_eq!(a.oob, b.oob);
    }
}
