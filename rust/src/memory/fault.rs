//! Fault models.
//!
//! Paper section 5.3: "The fault model is random bit flip. ... The
//! number of faulty bits is the product of the number of bits used to
//! represent weights of a CNN and the memory fault rate." We implement
//! that exactly: `n_flips = round(rate * total_bits)` *distinct* bit
//! positions drawn uniformly over the stored image (data + out-of-band
//! check storage — a scheme's own redundancy is equally exposed).
//!
//! Beyond the paper's uniform model the injector knows four more
//! deterministic models used by the ablations and the campaign engine:
//!
//! * [`FaultModel::Burst`] — non-overlapping runs of adjacent flipped
//!   bits, the failure signature of multi-cell upsets; probes where
//!   SEC-DED's single-error assumption breaks down.
//! * [`FaultModel::StuckAt`] — cells pinned to 0 or 1 rather than
//!   flipped: only cells whose stored value differs from the stuck
//!   value change, so the effective flip count depends on the image.
//! * [`FaultModel::RowBurst`] — bursts confined to length-aligned slots
//!   inside a configurable row stride, modelling DRAM row upsets.
//! * [`FaultModel::Hotspot`] — flips concentrated in one contiguous
//!   window covering a fraction of the image (localized damage, e.g. a
//!   failing bank region).
//! * [`FaultModel::HotspotAt`] — hotspot with a caller-pinned window
//!   start, so successive injections with fresh seeds keep hitting the
//!   same region; the time-varying scrub scenarios migrate the window
//!   between phases by changing the start fraction.
//!
//! Every model draws through [`FaultInjector::draw_positions`], so the
//! sharded bank's dirty tracking works unchanged for all of them.

use crate::ecc::Encoded;
use crate::util::rng::Rng;

/// Where a campaign injects its faults. [`FaultSite::Weights`] is the
/// storage site every PR so far exercised (bit flips in the protected
/// weight image); the compute sites strike transiently during
/// inference — [`FaultSite::Activations`] hits the buffer feeding a
/// dense layer's MACs, [`FaultSite::Accumulators`] hits the produced
/// output plane — and are answered by the compute-path guards
/// ([`crate::runtime::guard`]), not by storage ECC.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    Weights,
    Activations,
    Accumulators,
}

impl FaultSite {
    /// Stable tag — ledger keys, JSON reports, CLI. `parse` accepts
    /// every string `tag` produces.
    pub fn tag(&self) -> &'static str {
        match self {
            FaultSite::Weights => "weights",
            FaultSite::Activations => "activations",
            FaultSite::Accumulators => "accumulators",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<FaultSite> {
        match text {
            "weights" => Ok(FaultSite::Weights),
            "activations" => Ok(FaultSite::Activations),
            "accumulators" => Ok(FaultSite::Accumulators),
            _ => anyhow::bail!(
                "unknown fault site '{text}' (weights | activations | accumulators)"
            ),
        }
    }
}

/// Fault model selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultModel {
    /// Independent uniform bit flips (the paper's model).
    Uniform,
    /// Non-overlapping bursts of `len` adjacent flipped bits; the
    /// *total* flipped-bit budget still follows the rate
    /// (n_bursts = n_flips / len).
    Burst { len: u32 },
    /// Cells pinned to `bit` (0 or 1): drawn cells already storing
    /// `bit` are unaffected, so fewer than the budgeted bits may flip.
    StuckAt { bit: u8 },
    /// Bursts of `len` bits confined to len-aligned slots within rows
    /// of `row_bits` stored bits (DRAM row-upset signature). A trailing
    /// partial row keeps its whole slots exposed.
    RowBurst { row_bits: u64, len: u32 },
    /// Flips concentrated in one contiguous window covering `frac` of
    /// the stored image (window start is drawn per seed). The flip
    /// budget saturates at the window capacity — the window never
    /// widens to fit the budget.
    Hotspot { frac: f64 },
    /// Hotspot with a *fixed* window: the window starts at fraction
    /// `start` of the stored image instead of being drawn from the
    /// seed, so repeated injections with fresh seeds keep hammering the
    /// same region — the time-varying scrub scenarios move the window
    /// between phases by changing `start` (hotspot migration). Flip
    /// positions inside the window still vary per seed; the budget
    /// saturates at the window capacity like [`FaultModel::Hotspot`].
    HotspotAt { start: f64, frac: f64 },
}

impl FaultModel {
    /// Stable tag naming the model — ledger keys, JSON reports, seeds.
    /// `parse` accepts every string `tag` produces.
    pub fn tag(&self) -> String {
        match *self {
            FaultModel::Uniform => "uniform".to_string(),
            FaultModel::Burst { len } => format!("burst:{len}"),
            FaultModel::StuckAt { bit } => format!("stuckat:{bit}"),
            FaultModel::RowBurst { row_bits, len } => format!("rowburst:{row_bits}:{len}"),
            FaultModel::Hotspot { frac } => format!("hotspot:{frac}"),
            FaultModel::HotspotAt { start, frac } => format!("hotspotat:{start}:{frac}"),
        }
    }

    /// Parse a model tag (CLI `--fault-model`): `uniform`, `burst:LEN`,
    /// `stuckat:BIT`, `rowburst:ROWBITS:LEN`, `hotspot:FRAC`. Parameters
    /// may be omitted for defaults (`burst` = `burst:4`).
    pub fn parse(text: &str) -> anyhow::Result<FaultModel> {
        let (head, rest) = match text.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (text, None),
        };
        let bad = |what: &str| anyhow::anyhow!("bad {what} in fault model '{text}'");
        let model = match head {
            "uniform" => {
                anyhow::ensure!(rest.is_none(), "uniform takes no parameter (got '{text}')");
                FaultModel::Uniform
            }
            "burst" => FaultModel::Burst {
                len: rest.unwrap_or("4").parse().map_err(|_| bad("burst length"))?,
            },
            "stuckat" => {
                let bit: u8 = rest.unwrap_or("0").parse().map_err(|_| bad("stuck bit"))?;
                anyhow::ensure!(bit <= 1, "stuckat bit must be 0 or 1, got {bit}");
                FaultModel::StuckAt { bit }
            }
            "rowburst" => {
                let (row_bits, len) = match rest {
                    None => (8192, 4),
                    Some(r) => match r.split_once(':') {
                        Some((a, b)) => (
                            a.parse().map_err(|_| bad("row stride"))?,
                            b.parse().map_err(|_| bad("burst length"))?,
                        ),
                        None => (r.parse().map_err(|_| bad("row stride"))?, 4),
                    },
                };
                FaultModel::RowBurst { row_bits, len }
            }
            "hotspot" => {
                let frac: f64 = rest
                    .unwrap_or("0.05")
                    .parse()
                    .map_err(|_| bad("hotspot fraction"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&frac),
                    "hotspot fraction must be in [0, 1], got {frac}"
                );
                FaultModel::Hotspot { frac }
            }
            "hotspotat" => {
                let (start, frac) = match rest {
                    None => (0.5, 0.05),
                    Some(r) => match r.split_once(':') {
                        Some((a, b)) => (
                            a.parse().map_err(|_| bad("hotspot start"))?,
                            b.parse().map_err(|_| bad("hotspot fraction"))?,
                        ),
                        None => (r.parse().map_err(|_| bad("hotspot start"))?, 0.05),
                    },
                };
                anyhow::ensure!(
                    (0.0..=1.0).contains(&start) && (0.0..=1.0).contains(&frac),
                    "hotspotat start/fraction must be in [0, 1], got {start}:{frac}"
                );
                FaultModel::HotspotAt { start, frac }
            }
            _ => anyhow::bail!(
                "unknown fault model '{text}' \
                 (uniform | burst:LEN | stuckat:BIT | rowburst:ROWBITS:LEN | hotspot:FRAC | \
                 hotspotat:START:FRAC)"
            ),
        };
        Ok(model)
    }
}

/// Deterministic fault injector.
pub struct FaultInjector {
    pub model: FaultModel,
    rng: Rng,
}

impl FaultInjector {
    pub fn new(model: FaultModel, seed: u64) -> Self {
        FaultInjector {
            model,
            rng: Rng::new(seed),
        }
    }

    /// Number of faulty bits for a stored image at `rate` (paper
    /// semantics; rounds to nearest).
    pub fn flip_count(total_bits: u64, rate: f64) -> u64 {
        (total_bits as f64 * rate).round() as u64
    }

    /// Inject faults at `rate` into the image; returns bits flipped.
    pub fn inject(&mut self, enc: &mut Encoded, rate: f64) -> u64 {
        let n = Self::flip_count(enc.total_bits(), rate);
        self.inject_count(enc, n)
    }

    /// Inject a budget of `n` faulty bits (distinct positions; models
    /// may flip fewer — bursts round down to whole bursts, stuck-at
    /// skips cells already at the stuck value). Returns bits flipped.
    pub fn inject_count(&mut self, enc: &mut Encoded, n: u64) -> u64 {
        let positions = self.draw_positions(enc, n);
        let flipped = positions.len() as u64;
        for pos in positions {
            enc.flip_bit(pos);
        }
        flipped
    }

    /// Draw the distinct bit positions an `inject_count` call would
    /// flip, without flipping them — the sharded bank uses this to both
    /// flip and mark the shards the faults land in. For a given (model,
    /// seed, image) the sequence is identical to what
    /// `inject`/`inject_count` consume.
    pub fn draw_positions(&mut self, enc: &Encoded, n: u64) -> Vec<u64> {
        let total = enc.total_bits();
        if total == 0 || n == 0 {
            return Vec::new();
        }
        match self.model {
            FaultModel::Uniform => self.rng.distinct(total, n.min(total)),
            FaultModel::Burst { len } => {
                let len = u64::from(len.max(1));
                let bursts = (n / len).min(total / len);
                burst_positions(&mut self.rng, total, bursts, len)
            }
            FaultModel::StuckAt { bit } => {
                let stuck = bit != 0;
                self.rng
                    .distinct(total, n.min(total))
                    .into_iter()
                    .filter(|&pos| enc.get_bit(pos) != stuck)
                    .collect()
            }
            FaultModel::RowBurst { row_bits, len } => {
                let len = u64::from(len.max(1));
                let row = row_bits.max(len).min(total);
                let slots_per_row = row / len;
                let rows = total / row;
                // the trailing partial row is a (shorter) row too — its
                // whole slots stay exposed, or the rate would silently
                // undershoot on images that do not tile exactly
                let tail_slots = (total % row) / len;
                let total_slots = rows * slots_per_row + tail_slots;
                let bursts = (n / len).min(total_slots);
                if bursts == 0 {
                    return Vec::new();
                }
                let mut positions = Vec::with_capacity((bursts * len) as usize);
                for slot in self.rng.distinct(total_slots, bursts) {
                    let start = if slot < rows * slots_per_row {
                        slot / slots_per_row * row + slot % slots_per_row * len
                    } else {
                        rows * row + (slot - rows * slots_per_row) * len
                    };
                    positions.extend(start..start + len);
                }
                positions
            }
            FaultModel::Hotspot { frac } => {
                let start = self.rng.below(total);
                hotspot_positions(&mut self.rng, total, start, frac, n)
            }
            FaultModel::HotspotAt { start, frac } => {
                let start = ((total as f64 * start.clamp(0.0, 1.0)) as u64).min(total - 1);
                hotspot_positions(&mut self.rng, total, start, frac, n)
            }
        }
    }
}

/// Distinct positions inside the circular window of `frac * total` bits
/// starting at `start`. The budget saturates at the window capacity —
/// the window never widens to fit the budget, otherwise the model would
/// silently degenerate into a solid burst.
fn hotspot_positions(rng: &mut Rng, total: u64, start: u64, frac: f64, n: u64) -> Vec<u64> {
    let window = ((total as f64 * frac.clamp(0.0, 1.0)).ceil() as u64).clamp(1, total);
    let n = n.min(window);
    rng.distinct(window, n)
        .into_iter()
        .map(|off| (start + off) % total)
        .collect()
}

/// `bursts` non-overlapping runs of `len` adjacent bits in `[0, total)`
/// (requires `bursts * len <= total`). Sorted-gap construction: distinct
/// anchors drawn from the shrunken space `[0, total - bursts*(len-1))`
/// map to pairwise-disjoint intervals, so the flipped count is exact.
fn burst_positions(rng: &mut Rng, total: u64, bursts: u64, len: u64) -> Vec<u64> {
    if bursts == 0 {
        return Vec::new();
    }
    let mut anchors = rng.distinct(total - bursts * (len - 1), bursts);
    anchors.sort_unstable();
    let mut positions = Vec::with_capacity((bursts * len) as usize);
    for (i, anchor) in anchors.into_iter().enumerate() {
        let start = anchor + i as u64 * (len - 1);
        positions.extend(start..start + len);
    }
    positions
}

// ------------------------------------------------------------------ wear --

/// Parameters of the [`Wear`] aging process. Unlike [`FaultModel`]
/// (stateless per-injection distributions) wear is a *process*: damage
/// accumulates over simulated time, so the model carries state and
/// lives outside the `FaultModel` enum.
///
/// Two fault populations share one clock:
///
/// * **Stuck cells** — permanent damage. Each tick an expected
///   `wear_rate x total_bits` new cells (growing by `accel` per tick)
///   are pinned to a random value inside one contiguous window of the
///   image (`window_start`/`window_frac`): wear-out is localized —
///   write-hot rows age first — which is exactly the regime where
///   per-shard adaptive scrubbing can beat a uniform fixed interval.
///   Scrubbing corrects a stuck cell's *stored* image, but the cell
///   re-asserts its pinned value at the next strike — the per-cell
///   flip probability the Wilson estimator sees drifts upward.
/// * **Transient flips** — a uniform background at `transient_rate`
///   flips/bit/tick over the whole image, so the quiet shards are not
///   error-free (the scheduler must keep paying them *some* attention).
///   Worn cells also retain worse: `hot_rate` adds *extra* transient
///   flips confined to the wear window. This is the population scrub
///   policy actually differentiates on — an in-window transient that is
///   corrected before a partner flip arrives stays harmless, while two
///   uncorrected flips in one code block are permanent damage — whereas
///   stuck-at pairs form identically under any policy.
///
/// All populations use fractional-carry accounting: the realized count
/// after T ticks is exactly `floor(cumulative expectation)` (until the
/// `max_stuck_frac` cap or the window capacity saturates), which makes
/// the drift envelope a provable property rather than a statistical
/// one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WearParams {
    /// Expected transient flips per stored bit per tick (whole image).
    pub transient_rate: f64,
    /// Expected new stuck cells per stored bit per tick at tick 0.
    pub wear_rate: f64,
    /// Per-tick multiplicative growth of the stuck-cell arrival rate
    /// (`>= 1`); 1.0 means linear damage accumulation.
    pub accel: f64,
    /// Start of the wear window as a fraction of the stored image.
    pub window_start: f64,
    /// Width of the wear window as a fraction of the stored image.
    pub window_frac: f64,
    /// Saturation cap: stuck cells never exceed this fraction of the
    /// stored image (also capped by the window capacity).
    pub max_stuck_frac: f64,
    /// Extra transient flips per *window* bit per tick — the worn
    /// region's degraded retention.
    pub hot_rate: f64,
}

impl Default for WearParams {
    fn default() -> Self {
        // The window geometry mirrors the scrubsim migrate scenario's
        // first hotspot (inside one shard at a 16-way split); rates are
        // tuned so a few-hundred-tick run accumulates on the order of a
        // hundred stuck cells — enough damage drift to move the BER
        // estimate without saturating every window block past 1-bit
        // correctability — while the hot transient rate lands a few
        // in-window flips per tick, the population whose pairing-up
        // between scrubs the scrub policy actually controls.
        WearParams {
            transient_rate: 2e-7,
            wear_rate: 5e-7,
            accel: 1.01,
            window_start: 0.07,
            window_frac: 0.03,
            max_stuck_frac: 0.02,
            hot_rate: 2e-4,
        }
    }
}

impl WearParams {
    /// Stable tag naming the process — ledger fingerprints, JSON
    /// reports, CLI. `parse` accepts every string `tag` produces.
    pub fn tag(&self) -> String {
        format!(
            "wear:{}:{}:{}:{}:{}:{}:{}",
            self.transient_rate,
            self.wear_rate,
            self.accel,
            self.window_start,
            self.window_frac,
            self.max_stuck_frac,
            self.hot_rate
        )
    }

    /// Parse a wear tag:
    /// `wear[:TRANSIENT[:RATE[:ACCEL[:START[:FRAC[:CAP[:HOT]]]]]]]` —
    /// trailing parameters may be omitted for the defaults.
    pub fn parse(text: &str) -> anyhow::Result<WearParams> {
        let mut parts = text.split(':');
        anyhow::ensure!(
            parts.next() == Some("wear"),
            "unknown wear model '{text}' (wear:TRANSIENT:RATE:ACCEL:START:FRAC:CAP:HOT)"
        );
        let mut p = WearParams::default();
        let fields: [(&str, &mut f64); 7] = [
            ("transient rate", &mut p.transient_rate),
            ("wear rate", &mut p.wear_rate),
            ("acceleration", &mut p.accel),
            ("window start", &mut p.window_start),
            ("window fraction", &mut p.window_frac),
            ("stuck cap", &mut p.max_stuck_frac),
            ("hot transient rate", &mut p.hot_rate),
        ];
        let mut parts = parts.fuse();
        for (what, slot) in fields {
            match parts.next() {
                None => break,
                Some(raw) => {
                    *slot = raw
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad {what} in wear model '{text}'"))?;
                }
            }
        }
        anyhow::ensure!(
            parts.next().is_none(),
            "too many parameters in wear model '{text}'"
        );
        p.validate()?;
        Ok(p)
    }

    /// Range checks shared by `parse` and [`Wear::new`].
    pub fn validate(&self) -> anyhow::Result<()> {
        let unit = |v: f64| (0.0..=1.0).contains(&v);
        anyhow::ensure!(
            self.transient_rate.is_finite() && self.transient_rate >= 0.0,
            "wear transient rate must be finite and >= 0"
        );
        anyhow::ensure!(
            self.hot_rate.is_finite() && self.hot_rate >= 0.0,
            "wear hot transient rate must be finite and >= 0"
        );
        anyhow::ensure!(
            self.wear_rate.is_finite() && self.wear_rate >= 0.0,
            "wear rate must be finite and >= 0"
        );
        anyhow::ensure!(
            self.accel.is_finite() && self.accel >= 1.0,
            "wear acceleration must be finite and >= 1"
        );
        anyhow::ensure!(
            unit(self.window_start) && unit(self.window_frac) && unit(self.max_stuck_frac),
            "wear window start/fraction and stuck cap must be in [0, 1]"
        );
        Ok(())
    }
}

/// Stateful wear/aging fault process (see [`WearParams`]).
///
/// Drive it with one [`Wear::advance`] per simulated tick (damage
/// accrual), then ask [`Wear::strike_positions`] which stored bits
/// differ from what the damaged memory would read back — stuck cells
/// re-assert their pinned value even if a scrub just rewrote them,
/// plus this tick's transient flips. The caller flips exactly those
/// positions (e.g. via `ShardedBank::inject_positions`), keeping the
/// bank's dirty tracking correct.
pub struct Wear {
    params: WearParams,
    rng: Rng,
    /// Permanently damaged cells: stored-bit position -> pinned value.
    stuck: std::collections::BTreeMap<u64, bool>,
    /// Current stuck-cell arrival rate (grows by `accel` per tick).
    rate: f64,
    /// Fractional-carry accumulators (exact floor-of-expectation
    /// realization for stuck growth and transient counts).
    wear_carry: f64,
    transient_carry: f64,
    hot_carry: f64,
    ticks: u64,
}

impl Wear {
    pub fn new(params: WearParams, seed: u64) -> anyhow::Result<Wear> {
        params.validate()?;
        Ok(Wear {
            params,
            rng: Rng::new(seed),
            stuck: std::collections::BTreeMap::new(),
            rate: params.wear_rate,
            wear_carry: 0.0,
            transient_carry: 0.0,
            hot_carry: 0.0,
            ticks: 0,
        })
    }

    pub fn params(&self) -> WearParams {
        self.params
    }

    /// Stuck cells accumulated so far (monotone in tick count).
    pub fn stuck_cells(&self) -> u64 {
        self.stuck.len() as u64
    }

    /// Stuck-cell arrival rate for the *next* tick (flips/bit/tick).
    pub fn current_wear_rate(&self) -> f64 {
        self.rate
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Advance simulated time by one tick over an image of
    /// `total_bits` stored bits: pin `floor(rate x total + carry)` new
    /// cells inside the wear window, then accelerate the rate.
    pub fn advance(&mut self, total_bits: u64) {
        if total_bits == 0 {
            self.ticks += 1;
            return;
        }
        let window =
            ((total_bits as f64 * self.params.window_frac).ceil() as u64).clamp(1, total_bits);
        let start = ((total_bits as f64 * self.params.window_start) as u64).min(total_bits - 1);
        let cap = ((total_bits as f64 * self.params.max_stuck_frac) as u64).min(window);
        let expected = self.rate * total_bits as f64 + self.wear_carry;
        let budget = expected.floor().max(0.0) as u64;
        self.wear_carry = (expected - budget as f64).clamp(0.0, 1.0);
        for _ in 0..budget {
            if self.stuck.len() as u64 >= cap {
                // saturated: damage stops accruing, and the carry must
                // not bank the denied budget toward a burst later
                self.wear_carry = 0.0;
                break;
            }
            // deterministic linear probe inside the (circular) window:
            // collisions with already-stuck cells walk to the next cell
            let mut off = self.rng.below(window);
            let mut pos = (start + off) % total_bits;
            while self.stuck.contains_key(&pos) {
                off = (off + 1) % window;
                pos = (start + off) % total_bits;
            }
            let pinned = self.rng.next_u64() & 1 == 1;
            self.stuck.insert(pos, pinned);
        }
        self.rate = (self.rate * self.params.accel).min(1.0);
        self.ticks += 1;
    }

    /// Bit positions of `enc` that the damaged memory reads back
    /// differently from what is stored: every stuck cell whose stored
    /// bit is not its pinned value (re-assertion — a scrub's rewrite
    /// does not heal the cell), this tick's uniform background
    /// transient flips, and the worn window's extra `hot_rate`
    /// transients — all drawn outside the stuck set and deduplicated
    /// (a repeated position would flip back). Flipping exactly the
    /// returned positions brings the image to the damaged read-back
    /// state.
    ///
    /// RNG consumption here depends only on the image *size*, never on
    /// its contents, so two simulations fed the same seed see the same
    /// damage process no matter how their scrub policies respond.
    pub fn strike_positions(&mut self, enc: &Encoded) -> Vec<u64> {
        let total = enc.total_bits();
        if total == 0 {
            return Vec::new();
        }
        let mut positions: std::collections::BTreeSet<u64> = self
            .stuck
            .iter()
            .filter(|&(&pos, &pinned)| pos < total && enc.get_bit(pos) != pinned)
            .map(|(&pos, _)| pos)
            .collect();
        let expected = self.params.transient_rate * total as f64 + self.transient_carry;
        let n = expected.floor().max(0.0) as u64;
        self.transient_carry = (expected - n as f64).clamp(0.0, 1.0);
        if n > 0 {
            positions.extend(
                self.rng
                    .distinct(total, n.min(total))
                    .into_iter()
                    .filter(|pos| !self.stuck.contains_key(pos)),
            );
        }
        let window =
            ((total as f64 * self.params.window_frac).ceil() as u64).clamp(1, total);
        let start = ((total as f64 * self.params.window_start) as u64).min(total - 1);
        let expected = self.params.hot_rate * window as f64 + self.hot_carry;
        let h = expected.floor().max(0.0) as u64;
        self.hot_carry = (expected - h as f64).clamp(0.0, 1.0);
        if h > 0 {
            positions.extend(
                self.rng
                    .distinct(window, h.min(window))
                    .into_iter()
                    .map(|off| (start + off) % total)
                    .filter(|pos| !self.stuck.contains_key(pos)),
            );
        }
        positions.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image(nbytes: usize) -> Encoded {
        Encoded {
            data: vec![0u8; nbytes],
            oob: vec![0u8; nbytes / 8],
            n: nbytes,
        }
    }

    fn ones_of(enc: &Encoded) -> u64 {
        enc.data
            .iter()
            .chain(&enc.oob)
            .map(|b| u64::from(b.count_ones()))
            .sum()
    }

    #[test]
    fn count_semantics_match_paper() {
        // 1e6 weight bits at 1e-3 -> exactly 1000 flips.
        assert_eq!(FaultInjector::flip_count(1_000_000, 1e-3), 1000);
        // sub-one expectation rounds: 1e4 bits at 1e-5 -> 0 flips.
        assert_eq!(FaultInjector::flip_count(10_000, 1e-5), 0);
        assert_eq!(FaultInjector::flip_count(10_000, 6e-5), 1);
    }

    #[test]
    fn uniform_flips_exact_distinct_count() {
        let mut enc = image(1024);
        let mut inj = FaultInjector::new(FaultModel::Uniform, 42);
        let n = inj.inject(&mut enc, 1e-2); // 1024*8*1.125 bits * 1e-2 ≈ 92
        assert_eq!(ones_of(&enc), n, "flips must hit distinct bits");
    }

    #[test]
    fn oob_bits_are_exposed_too() {
        let mut hit_oob = false;
        for seed in 0..50 {
            let mut enc = image(64);
            let mut inj = FaultInjector::new(FaultModel::Uniform, seed);
            inj.inject_count(&mut enc, 40);
            if enc.oob.iter().any(|&b| b != 0) {
                hit_oob = true;
                break;
            }
        }
        assert!(hit_oob, "faults must be able to land in check storage");
    }

    #[test]
    fn burst_flips_exact_adjacent_runs() {
        for seed in 0..20 {
            let mut enc = image(1024);
            let mut inj = FaultInjector::new(FaultModel::Burst { len: 4 }, seed);
            let flipped = inj.inject_count(&mut enc, 8);
            assert_eq!(flipped, 8, "two bursts of 4, never fewer");
            assert_eq!(ones_of(&enc), 8, "bursts must not self-overlap");
        }
        // and the drawn positions are two disjoint runs of 4 adjacent bits
        let enc = image(1024);
        let mut inj = FaultInjector::new(FaultModel::Burst { len: 4 }, 7);
        let mut pos = inj.draw_positions(&enc, 8);
        pos.sort_unstable();
        assert_eq!(pos.len(), 8);
        for run in pos.chunks(4) {
            for k in 1..4 {
                assert_eq!(run[k], run[0] + k as u64, "burst must be adjacent bits");
            }
        }
        assert!(pos[4] > pos[3], "bursts must be distinct");
    }

    #[test]
    fn burst_saturates_at_image_capacity() {
        // 8 data bytes + 1 oob byte = 72 bits; a 720-bit budget of
        // 8-bit bursts clamps to 9 whole bursts tiling the image.
        let mut enc = image(8);
        let mut inj = FaultInjector::new(FaultModel::Burst { len: 8 }, 3);
        let flipped = inj.inject_count(&mut enc, 720);
        assert_eq!(flipped, 72);
        assert_eq!(ones_of(&enc), 72);
    }

    #[test]
    fn stuckat_pins_cells_instead_of_flipping() {
        // all-zero image: stuck-at-1 flips the full budget...
        let mut enc = image(256);
        let mut inj = FaultInjector::new(FaultModel::StuckAt { bit: 1 }, 9);
        assert_eq!(inj.inject_count(&mut enc, 40), 40);
        assert_eq!(ones_of(&enc), 40);
        // ...stuck-at-0 flips nothing.
        let mut enc = image(256);
        let mut inj = FaultInjector::new(FaultModel::StuckAt { bit: 0 }, 9);
        assert_eq!(inj.inject_count(&mut enc, 40), 0);
        assert_eq!(ones_of(&enc), 0);
        // all-ones image: stuck-at-0 clears exactly the budget.
        let mut enc = image(256);
        enc.data.iter_mut().for_each(|b| *b = 0xFF);
        enc.oob.iter_mut().for_each(|b| *b = 0xFF);
        let total = enc.total_bits();
        let mut inj = FaultInjector::new(FaultModel::StuckAt { bit: 0 }, 11);
        assert_eq!(inj.inject_count(&mut enc, 40), 40);
        assert_eq!(ones_of(&enc), total - 40);
    }

    #[test]
    fn rowburst_stays_inside_aligned_row_slots() {
        let enc = image(1024); // 9216 stored bits
        let (row_bits, len) = (256u64, 4u64);
        let mut inj = FaultInjector::new(
            FaultModel::RowBurst { row_bits, len: len as u32 },
            13,
        );
        let pos = inj.draw_positions(&enc, 32);
        assert_eq!(pos.len(), 32, "8 bursts of 4");
        let distinct: std::collections::HashSet<_> = pos.iter().collect();
        assert_eq!(distinct.len(), 32, "slots are disjoint");
        for run in pos.chunks(len as usize) {
            assert_eq!(run[0] % len, 0, "burst start is slot-aligned");
            let row = run[0] / row_bits;
            for (k, &p) in run.iter().enumerate() {
                assert_eq!(p, run[0] + k as u64, "burst is adjacent bits");
                assert_eq!(p / row_bits, row, "burst never crosses a row");
            }
        }
    }

    #[test]
    fn rowburst_tail_partial_row_stays_exposed() {
        // 72 stored bits, 32-bit rows: 2 full rows (16 slots of 4) plus
        // an 8-bit tail holding 2 more slots. A saturating budget must
        // reach all 18 slots = every bit of the image.
        let mut enc = image(8);
        let mut inj = FaultInjector::new(FaultModel::RowBurst { row_bits: 32, len: 4 }, 5);
        let flipped = inj.inject_count(&mut enc, 720);
        assert_eq!(flipped, 72, "tail slots must be drawable");
        assert_eq!(ones_of(&enc), 72);
    }

    #[test]
    fn hotspot_confines_flips_to_one_window() {
        let enc = image(4096); // 36864 stored bits
        let total = enc.total_bits();
        let frac = 0.05;
        let mut inj = FaultInjector::new(FaultModel::Hotspot { frac }, 17);
        let pos = inj.draw_positions(&enc, 64);
        assert_eq!(pos.len(), 64);
        let window = (total as f64 * frac).ceil() as u64;
        // All positions fit inside one circular window of `window` bits
        // iff the largest circular gap between consecutive positions
        // leaves a covering arc no wider than the window.
        let mut sorted = pos.clone();
        sorted.sort_unstable();
        let mut max_gap = sorted[0] + total - sorted[sorted.len() - 1];
        for pair in sorted.windows(2) {
            max_gap = max_gap.max(pair[1] - pair[0]);
        }
        assert!(
            total - max_gap < window,
            "hotspot flips span {} bits, window is {}",
            total - max_gap,
            window
        );
    }

    #[test]
    fn hotspot_budget_saturates_at_window_capacity() {
        // 1152 stored bits, 2% window = 24 bits: a 100-bit budget must
        // not widen the window — it flips exactly the 24 window bits.
        let mut enc = image(128);
        let mut inj = FaultInjector::new(FaultModel::Hotspot { frac: 0.02 }, 21);
        let flipped = inj.inject_count(&mut enc, 100);
        assert_eq!(flipped, 24);
        assert_eq!(ones_of(&enc), 24);
    }

    #[test]
    fn hotspotat_window_is_stable_across_seeds() {
        // Fresh seeds redraw the positions but never the window: every
        // drawn bit stays inside [start*total, start*total + window).
        let enc = image(4096);
        let total = enc.total_bits();
        let (start_frac, frac) = (0.25, 0.03);
        let start = (total as f64 * start_frac) as u64;
        let window = (total as f64 * frac).ceil() as u64;
        let mut seen_distinct = false;
        let mut prev: Option<Vec<u64>> = None;
        for seed in 0..8 {
            let mut inj =
                FaultInjector::new(FaultModel::HotspotAt { start: start_frac, frac }, seed);
            let pos = inj.draw_positions(&enc, 40);
            assert_eq!(pos.len(), 40);
            for &p in &pos {
                let off = (p + total - start) % total;
                assert!(off < window, "bit {p} outside the fixed window");
            }
            if prev.as_ref().is_some_and(|q| *q != pos) {
                seen_distinct = true;
            }
            prev = Some(pos);
        }
        assert!(seen_distinct, "positions must still vary with the seed");
    }

    #[test]
    fn site_tags_roundtrip_through_parse() {
        for site in [
            FaultSite::Weights,
            FaultSite::Activations,
            FaultSite::Accumulators,
        ] {
            assert_eq!(FaultSite::parse(site.tag()).unwrap(), site);
        }
        assert!(FaultSite::parse("cache").is_err());
    }

    #[test]
    fn tags_roundtrip_through_parse() {
        let models = [
            FaultModel::Uniform,
            FaultModel::Burst { len: 4 },
            FaultModel::StuckAt { bit: 1 },
            FaultModel::RowBurst { row_bits: 8192, len: 2 },
            FaultModel::Hotspot { frac: 0.05 },
            FaultModel::HotspotAt { start: 0.25, frac: 0.05 },
        ];
        for m in models {
            assert_eq!(FaultModel::parse(&m.tag()).unwrap(), m, "{}", m.tag());
        }
        assert_eq!(FaultModel::parse("burst").unwrap(), FaultModel::Burst { len: 4 });
        assert_eq!(
            FaultModel::parse("hotspotat:0.3").unwrap(),
            FaultModel::HotspotAt { start: 0.3, frac: 0.05 }
        );
        assert!(FaultModel::parse("hotspotat:1.5:0.05").is_err());
        assert!(FaultModel::parse("stuckat:2").is_err());
        assert!(FaultModel::parse("nope").is_err());
        assert!(FaultModel::parse("burst:x").is_err());
        assert!(
            FaultModel::parse("uniform:0.01").is_err(),
            "stray parameters must not be silently discarded"
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let models = [
            FaultModel::Uniform,
            FaultModel::Burst { len: 3 },
            FaultModel::StuckAt { bit: 1 },
            FaultModel::RowBurst { row_bits: 128, len: 2 },
            FaultModel::Hotspot { frac: 0.1 },
            FaultModel::HotspotAt { start: 0.6, frac: 0.1 },
        ];
        for m in models {
            let mut a = image(256);
            let mut b = image(256);
            FaultInjector::new(m, 99).inject_count(&mut a, 50);
            FaultInjector::new(m, 99).inject_count(&mut b, 50);
            assert_eq!(a.data, b.data, "{}", m.tag());
            assert_eq!(a.oob, b.oob, "{}", m.tag());
        }
    }

    // -------------------------------------------------------------- wear --

    fn wear_params() -> WearParams {
        WearParams {
            transient_rate: 1e-4,
            wear_rate: 1e-3,
            accel: 1.05,
            window_start: 0.25,
            window_frac: 0.10,
            max_stuck_frac: 0.05,
            hot_rate: 0.0,
        }
    }

    #[test]
    fn wear_tag_roundtrips_and_defaults() {
        let p = wear_params();
        assert_eq!(WearParams::parse(&p.tag()).unwrap(), p);
        assert_eq!(WearParams::parse("wear").unwrap(), WearParams::default());
        // trailing parameters default positionally
        let partial = WearParams::parse("wear:1e-6:2e-5").unwrap();
        assert_eq!(partial.transient_rate, 1e-6);
        assert_eq!(partial.wear_rate, 2e-5);
        assert_eq!(partial.accel, WearParams::default().accel);
        assert!(WearParams::parse("wear:x").is_err());
        assert!(WearParams::parse("wear:1:1:0.5").is_err(), "accel < 1");
        assert!(WearParams::parse("wear:0:0:1:2").is_err(), "start > 1");
        assert!(WearParams::parse("wear:0:0:1:0:0:0:0:9").is_err(), "extra");
        assert!(WearParams::parse("uniform").is_err());
    }

    #[test]
    fn wear_is_deterministic_per_seed() {
        let enc = image(1024);
        let mut a = Wear::new(wear_params(), 77).unwrap();
        let mut b = Wear::new(wear_params(), 77).unwrap();
        for _ in 0..20 {
            a.advance(enc.total_bits());
            b.advance(enc.total_bits());
            assert_eq!(a.strike_positions(&enc), b.strike_positions(&enc));
        }
        assert_eq!(a.stuck_cells(), b.stuck_cells());
    }

    #[test]
    fn wear_stuck_set_grows_to_floor_of_expectation() {
        // 9216 stored bits at 1e-3/bit/tick, accel 1.05: the realized
        // stuck count after each tick is exactly floor(cumulative
        // expectation) until the cap binds (carry accounting is exact).
        let enc = image(1024);
        let total = enc.total_bits();
        let p = wear_params();
        let mut wear = Wear::new(p, 5).unwrap();
        let mut expected = 0.0f64;
        let mut rate = p.wear_rate;
        let cap = ((total as f64 * p.max_stuck_frac) as u64)
            .min((total as f64 * p.window_frac).ceil() as u64);
        let mut prev = 0;
        for t in 0..40 {
            wear.advance(total);
            expected += rate * total as f64;
            rate *= p.accel;
            let got = wear.stuck_cells();
            assert!(got >= prev, "stuck set must be monotone (tick {t})");
            prev = got;
            if got < cap {
                assert_eq!(got, expected.floor() as u64, "tick {t}");
            } else {
                assert_eq!(got, cap, "tick {t}: saturated at the cap");
            }
        }
        assert_eq!(prev, cap, "40 ticks at these rates must saturate");
    }

    #[test]
    fn wear_strikes_stay_inside_window_and_reassert_after_scrub() {
        let mut enc = image(1024);
        let total = enc.total_bits();
        let p = WearParams {
            transient_rate: 0.0,
            ..wear_params()
        };
        let mut wear = Wear::new(p, 3).unwrap();
        for _ in 0..10 {
            wear.advance(total);
        }
        let start = (total as f64 * p.window_start) as u64;
        let window = (total as f64 * p.window_frac).ceil() as u64;
        let strikes = wear.strike_positions(&enc);
        assert!(!strikes.is_empty());
        for &pos in &strikes {
            let off = (pos + total - start) % total;
            assert!(off < window, "stuck cell {pos} outside the wear window");
        }
        for &pos in &strikes {
            enc.flip_bit(pos);
        }
        // damaged state reached: nothing further to assert this tick
        assert!(wear.strike_positions(&enc).is_empty());
        // a "scrub" rewriting the stored image does not heal the cells:
        // every pinned cell re-asserts at the next strike
        let mut sorted = strikes.clone();
        sorted.sort_unstable();
        for &pos in &strikes {
            enc.flip_bit(pos); // restore clean stored image
        }
        let mut again = wear.strike_positions(&enc);
        again.sort_unstable();
        assert_eq!(again, sorted, "stuck cells must re-assert after rewrite");
    }

    #[test]
    fn wear_transients_follow_carry_and_avoid_stuck_cells() {
        // wear_rate 0: every strike is transient. 1e-4 over 9216 bits
        // = 0.9216/tick, so exact carry realizes floor(0.9216 * 10) = 9
        // strikes over 10 ticks.
        let enc = image(1024);
        let total = enc.total_bits();
        let p = WearParams {
            wear_rate: 0.0,
            ..wear_params()
        };
        let mut wear = Wear::new(p, 11).unwrap();
        let mut transients = 0usize;
        for _ in 0..10 {
            wear.advance(total);
            transients += wear.strike_positions(&enc).len();
        }
        assert_eq!(transients, 9, "carry must realize floor of expectation");

        // with stuck cells present, transient draws skip the stuck set:
        // strike positions are always pairwise distinct.
        let p = WearParams {
            transient_rate: 5e-3,
            ..wear_params()
        };
        let mut wear = Wear::new(p, 13).unwrap();
        for _ in 0..10 {
            wear.advance(total);
            let strikes = wear.strike_positions(&enc);
            let distinct: std::collections::HashSet<_> = strikes.iter().collect();
            assert_eq!(distinct.len(), strikes.len(), "strikes must be distinct");
        }
    }

    #[test]
    fn wear_hot_transients_stay_inside_window() {
        // hot_rate only: 1e-3 over a ceil(9216 * 0.10) = 922-bit window
        // = 0.922/tick -> exactly floor(9.22) = 9 strikes over 10
        // ticks, every one inside the window.
        let enc = image(1024);
        let total = enc.total_bits();
        let p = WearParams {
            transient_rate: 0.0,
            wear_rate: 0.0,
            hot_rate: 1e-3,
            ..wear_params()
        };
        let start = (total as f64 * p.window_start) as u64;
        let window = (total as f64 * p.window_frac).ceil() as u64;
        let mut wear = Wear::new(p, 21).unwrap();
        let mut hot = 0usize;
        for _ in 0..10 {
            wear.advance(total);
            for pos in wear.strike_positions(&enc) {
                let off = (pos + total - start) % total;
                assert!(off < window, "hot transient {pos} outside the window");
                hot += 1;
            }
        }
        assert_eq!(hot, 9, "hot carry must realize floor of expectation");
    }
}
