//! The simulated memory subsystem holding encoded CNN weights.
//!
//! * [`fault`] — fault models: uniform random bit flips with the paper's
//!   exact count semantics, plus a burst model (adjacent-bit upsets) for
//!   the ablation study.
//! * [`bank`] — `MemoryBank`: an encoded weight image + its protection
//!   strategy; supports fault injection, protected reads and scrubbing.
//! * [`shard`] — `ShardedBank`: the same stored image split into S
//!   block-aligned shards, scrubbed/decoded by a scoped-thread worker
//!   pool with per-shard stats and dirty tracking — the serving path's
//!   store, enabling incremental (delta) weight refresh.

pub mod bank;
pub mod fault;
pub mod shard;

pub use bank::MemoryBank;
pub use fault::{FaultInjector, FaultModel};
pub use shard::{plan_shards, ShardState, ShardedBank};
