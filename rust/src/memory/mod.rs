//! The simulated memory subsystem holding encoded CNN weights.
//!
//! * [`fault`] — deterministic fault models: uniform random bit flips
//!   with the paper's exact count semantics, plus burst (adjacent-bit
//!   upsets), stuck-at (cells pinned to 0/1), row-burst (DRAM row
//!   upsets) and hotspot (localized damage) models for the ablations
//!   and the campaign engine. All models draw through
//!   `FaultInjector::draw_positions`, so shard dirty tracking works
//!   unchanged for every one of them.
//! * [`bank`] — `MemoryBank`: an encoded weight image + its protection
//!   strategy; supports fault injection, protected reads and scrubbing.
//! * [`shard`] — `ShardedBank`: the same stored image split into S
//!   block-aligned shards, scrubbed/decoded by a scoped-thread worker
//!   pool with per-shard stats and dirty tracking — the serving path's
//!   store, enabling incremental (delta) weight refresh. Its `run_jobs`
//!   pool is reused by `harness::campaign` to fan experiment cells out
//!   over workers.

pub mod bank;
pub mod fault;
pub mod shard;

pub use bank::MemoryBank;
pub use fault::{FaultInjector, FaultModel};
pub use shard::{plan_shards, run_jobs, ShardState, ShardedBank};
