//! The simulated memory subsystem holding encoded CNN weights.
//!
//! * [`fault`] — deterministic fault models: uniform random bit flips
//!   with the paper's exact count semantics, plus burst (adjacent-bit
//!   upsets), stuck-at (cells pinned to 0/1), row-burst (DRAM row
//!   upsets) and hotspot (localized damage) models for the ablations
//!   and the campaign engine. All models draw through
//!   `FaultInjector::draw_positions`, so shard dirty tracking works
//!   unchanged for every one of them. The same module hosts the
//!   stateful [`fault::Wear`] aging process (stuck-at damage
//!   accumulating over simulated time inside a wear window, an
//!   elevated in-window transient rate from degraded retention, plus a
//!   uniform transient background) that the closed-loop accuracy
//!   simulation drives via `ShardedBank::inject_positions`.
//! * [`bank`] — `MemoryBank`: an encoded weight image + its protection
//!   strategy; supports fault injection, protected reads and scrubbing.
//! * [`shard`] — `ShardedBank`: the same stored image split into S
//!   block-aligned shards, scrubbed/decoded over the persistent worker
//!   pool with per-shard stats and dirty tracking — the serving path's
//!   store, enabling incremental (delta) weight refresh. Trial resets
//!   are copy-on-write: only fault-touched code blocks are copied back
//!   from the pristine image.
//! * [`pool`] — the persistent worker pool (long-lived parked threads,
//!   shared injector + per-worker stealable run queues, a scope-style
//!   borrow API) and the per-worker scratch arenas (recycled
//!   `Vec<i8>`/`Vec<f32>` freelists). `run_jobs` is the compatibility
//!   wrapper shard passes, `harness::campaign` cells/trials and the
//!   serving scrub loop all fan out through.
//! * [`scheduler`] — the adaptive scrub scheduler: a per-shard online
//!   bit-error-rate estimator (exponentially weighted error arrivals
//!   with Wilson confidence bounds) feeding per-shard scrub deadlines.
//!   Hot shards clamp to the base interval, provably-clean shards
//!   decay toward a configured maximum; the serving loop and the
//!   `harness::scrubsim` scenarios both drive it. The same module
//!   hosts the fleet arbitration core ([`scheduler::arbitrate`],
//!   [`scheduler::FleetArbitration`]): cross-model urgency ranking of
//!   due shards under one bit budget, with a deferral-capped
//!   starvation guarantee and per-model deficit accounting — the pure
//!   planner behind `coordinator::fleet`.

pub mod bank;
pub mod fault;
pub mod pool;
pub mod scheduler;
pub mod shard;

pub use bank::MemoryBank;
pub use fault::{FaultInjector, FaultModel, FaultSite, Wear, WearParams};
pub use pool::{run_jobs, Pool};
pub use scheduler::{
    arbitrate, gbps_to_bits_per_wakeup, FleetArbitration, FleetGrant, ModelDeficit,
    SchedulerConfig, ScrubDemand, ScrubPolicy, ScrubScheduler, ShardSchedule,
};
pub use shard::{plan_shards, ShardState, ShardedBank};
