//! The simulated memory subsystem holding encoded CNN weights.
//!
//! * [`fault`] — fault models: uniform random bit flips with the paper's
//!   exact count semantics, plus a burst model (adjacent-bit upsets) for
//!   the ablation study.
//! * [`bank`] — `MemoryBank`: an encoded weight image + its protection
//!   strategy; supports fault injection, protected reads and scrubbing.

pub mod bank;
pub mod fault;

pub use bank::MemoryBank;
pub use fault::{FaultModel, FaultInjector};
