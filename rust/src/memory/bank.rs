//! `MemoryBank`: the stored, protected weight memory of one model.
//!
//! Owns the encoded image plus its protection strategy; the coordinator
//! holds one bank per served model. Reads decode into an int8 weight
//! buffer (correcting what the code allows); `scrub` heals the stored
//! image in place; `inject` lets the environment (or the Table-2
//! harness) flip stored bits.

use crate::ecc::{DecodeStats, Encoded, Protection};
use crate::memory::fault::{FaultInjector, FaultModel};

pub struct MemoryBank {
    strategy: Box<dyn Protection>,
    image: Encoded,
    /// Pristine copy for trial resets (Table 2 runs 10 trials/cell).
    pristine: Encoded,
    /// Cumulative decode statistics (reported by the coordinator).
    pub lifetime: DecodeStats,
    /// Cumulative bits injected.
    pub faults_injected: u64,
}

impl MemoryBank {
    pub fn new(strategy: Box<dyn Protection>, weights: &[i8]) -> anyhow::Result<Self> {
        let image = strategy.encode(weights)?;
        Ok(MemoryBank {
            pristine: image.clone(),
            image,
            strategy,
            lifetime: DecodeStats::default(),
            faults_injected: 0,
        })
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn n_weights(&self) -> usize {
        self.image.n
    }

    /// Stored bits (data + check storage) — fault-rate denominator.
    pub fn total_bits(&self) -> u64 {
        self.image.total_bits()
    }

    /// Space overhead actually incurred by the stored image.
    pub fn overhead(&self) -> f64 {
        self.image.oob.len() as f64 / self.image.data.len() as f64
    }

    /// Inject faults at `rate` with the given model and seed.
    pub fn inject(&mut self, model: FaultModel, rate: f64, seed: u64) -> u64 {
        let mut inj = FaultInjector::new(model, seed);
        let n = inj.inject(&mut self.image, rate);
        self.faults_injected += n;
        n
    }

    /// Protected read: decode the stored image into `out`.
    pub fn read(&mut self, out: &mut [i8]) -> DecodeStats {
        assert_eq!(out.len(), self.image.n);
        let stats = self.strategy.decode(&self.image, out);
        self.lifetime.add(&stats);
        stats
    }

    /// Scrub pass: correct latent errors in the stored image.
    pub fn scrub(&mut self) -> DecodeStats {
        let stats = self.strategy.scrub(&mut self.image);
        self.lifetime.add(&stats);
        stats
    }

    /// Reset the image to its pristine (fault-free) state.
    pub fn reset(&mut self) {
        self.image = self.pristine.clone();
    }

    /// The stored image (shard-equivalence tests compare it against the
    /// sharded path's image).
    pub fn image(&self) -> &Encoded {
        &self.image
    }

    /// Re-wrap this bank's stored image as a [`ShardedBank`] with the
    /// given shard/worker counts — no re-encode, the image moves as-is.
    pub fn into_sharded(self, shards: usize, workers: usize) -> crate::memory::ShardedBank {
        crate::memory::ShardedBank::from_encoded(self.strategy, self.image, shards, workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::strategy_by_name;
    use crate::util::rng::Rng;

    fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                if i % 8 == 7 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(128) as i64 - 64) as i8
                }
            })
            .collect()
    }

    #[test]
    fn read_after_reset_is_exact() {
        let w = wot_weights(256, 1);
        let mut bank =
            MemoryBank::new(strategy_by_name("in-place").unwrap(), &w).unwrap();
        bank.inject(FaultModel::Uniform, 0.01, 3);
        bank.reset();
        let mut out = vec![0i8; w.len()];
        let stats = bank.read(&mut out);
        assert_eq!(out, w);
        assert_eq!(stats.corrected + stats.detected, 0);
    }

    #[test]
    fn low_rate_faults_fully_corrected() {
        let w = wot_weights(8192, 2);
        for name in ["ecc", "in-place"] {
            let mut bank = MemoryBank::new(strategy_by_name(name).unwrap(), &w).unwrap();
            // rate so low that two flips in one 64-bit block are unlikely
            bank.inject(FaultModel::Uniform, 1e-4, 7);
            let mut out = vec![0i8; w.len()];
            let stats = bank.read(&mut out);
            assert_eq!(out, w, "{name} at 1e-4 must fully correct");
            assert!(stats.corrected >= 1);
            assert_eq!(stats.detected, 0);
        }
    }

    #[test]
    fn scrub_then_clean_read() {
        let w = wot_weights(1024, 3);
        let mut bank = MemoryBank::new(strategy_by_name("in-place").unwrap(), &w).unwrap();
        bank.inject(FaultModel::Uniform, 1e-4, 11);
        bank.scrub();
        let mut out = vec![0i8; w.len()];
        let stats = bank.read(&mut out);
        assert_eq!(stats.corrected, 0, "scrub must have healed the image");
        assert_eq!(out, w);
    }

    #[test]
    fn overhead_accounting() {
        let w = wot_weights(1024, 4);
        for (name, ov) in [("faulty", 0.0), ("zero", 0.125), ("ecc", 0.125), ("in-place", 0.0)] {
            let bank = MemoryBank::new(strategy_by_name(name).unwrap(), &w).unwrap();
            assert!((bank.overhead() - ov).abs() < 1e-9, "{name}");
        }
    }
}
