//! `ShardedBank`: the protected weight memory of one model, split into S
//! independently scrubbable, block-aligned shards.
//!
//! The stored image stays contiguous (it models one region of physical
//! memory), but every decode/scrub pass runs per shard through the
//! `Protection` range APIs, fanned out over the persistent worker pool
//! ([`crate::memory::pool`] — long-lived parked threads, no per-pass
//! spawn/join).
//! Shard workers iterate 512-byte *tiles* (the word-parallel engine of
//! `ecc::tile`), not blocks: a clean tile is proven clean by one
//! OR-reduction, so the common fault-free epoch costs a copy (decode)
//! or nothing (scrub) instead of per-block syndrome LUT walks.
//! Each shard carries its own `DecodeStats` and a dirty bit: fault
//! injection marks the shards its flips land in, scrubbing marks shards
//! whose stored bytes it modified, and the serving scrub loop ships
//! *only* dirty shards to the inference thread as weight deltas.
//!
//! A `ShardedBank` with one shard and one worker behaves bit-identically
//! to the whole-buffer [`MemoryBank`](crate::memory::MemoryBank) path
//! (same fault-position sequence per seed, same decode output, same
//! stats) — the shard-equivalence proptests pin this down.

use crate::ecc::{DecodeOutcome, DecodeStats, Encoded, Protection, DETECTED_BLOCK_CAP};
use crate::memory::fault::{FaultInjector, FaultModel};
use crate::memory::pool::{self, run_jobs};
use crate::model::manifest::Layer;
use std::collections::BTreeMap;

/// Per-shard bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct ShardState {
    /// Byte window `[start, end)` into the stored image's data bytes.
    pub range: (usize, usize),
    /// Cumulative decode/scrub statistics for this shard.
    pub lifetime: DecodeStats,
    /// Statistics of the most recent scrub pass.
    pub last_scrub: DecodeStats,
    /// Number of scrub passes over this shard.
    pub scrubs: u64,
    /// Stored bytes (or decode output) may differ from what the serving
    /// layer last refreshed: set by injection/scrub, cleared by
    /// [`ShardedBank::take_dirty`].
    pub dirty: bool,
}

/// Plan block-aligned shard byte ranges over `data_len` data bytes.
/// Returns at most `shards` contiguous ranges tiling `[0, data_len)`;
/// fewer when there are not enough blocks to go around. A ragged final
/// block (only possible for byte-granular codes) lands in the last shard.
pub fn plan_shards(data_len: usize, block_bytes: usize, shards: usize) -> Vec<(usize, usize)> {
    let block = block_bytes.max(1);
    let nblocks = data_len.div_ceil(block).max(1);
    let s = shards.max(1).min(nblocks);
    let per = nblocks.div_ceil(s);
    let mut ranges = Vec::with_capacity(s);
    for i in 0..s {
        let lo = (i * per * block).min(data_len);
        let hi = ((i + 1) * per * block).min(data_len);
        if lo >= hi && i > 0 {
            break;
        }
        ranges.push((lo, hi));
    }
    ranges
}

pub struct ShardedBank {
    strategy: Box<dyn Protection>,
    image: Encoded,
    /// Pristine copy for trial resets (Table 2 runs 10 trials/cell).
    pristine: Encoded,
    shards: Vec<ShardState>,
    workers: usize,
    /// Code-block indices whose stored bytes may differ from pristine:
    /// fault injection records every hit block, and a scrub pass only
    /// ever writes inside blocks already carrying a fault (a zero
    /// syndrome is never "corrected"). `None` after a direct
    /// [`ShardedBank::image_mut`] mutation — [`ShardedBank::reset`]
    /// then falls back to a full pristine restore.
    touched: Option<Vec<usize>>,
    /// Detected-uncorrectable block indices (absolute, image-wide), keyed
    /// by owning shard. *Replacement* semantics: every outcome-reporting
    /// pass over a shard replaces that shard's entry with what the final
    /// decode of that pass saw — a block healed by a later scrub drops
    /// out instead of lingering as a stale detection. Bounded at
    /// [`DETECTED_BLOCK_CAP`] entries bank-wide (overflow flagged), the
    /// same discipline as the copy-on-write `touched` log.
    detected: BTreeMap<usize, Vec<usize>>,
    detected_overflow: bool,
    /// Cumulative decode statistics across all shards.
    pub lifetime: DecodeStats,
    /// Cumulative bits injected.
    pub faults_injected: u64,
}

impl ShardedBank {
    /// Encode `weights` once and split the stored image into (at most)
    /// `shards` block-aligned shards scrubbed by `workers` threads.
    pub fn new(
        strategy: Box<dyn Protection>,
        weights: &[i8],
        shards: usize,
        workers: usize,
    ) -> anyhow::Result<Self> {
        let image = strategy.encode(weights)?;
        Ok(Self::from_encoded(strategy, image, shards, workers))
    }

    /// Wrap an already-encoded image (used by `MemoryBank::into_sharded`).
    pub fn from_encoded(
        strategy: Box<dyn Protection>,
        image: Encoded,
        shards: usize,
        workers: usize,
    ) -> Self {
        let ranges = plan_shards(image.data.len(), strategy.block_bytes(), shards);
        let shards = ranges
            .into_iter()
            .map(|range| ShardState {
                range,
                ..ShardState::default()
            })
            .collect();
        ShardedBank {
            pristine: image.clone(),
            image,
            strategy,
            shards,
            workers: workers.max(1),
            touched: Some(Vec::new()),
            detected: BTreeMap::new(),
            detected_overflow: false,
            lifetime: DecodeStats::default(),
            faults_injected: 0,
        }
    }

    /// A sensible worker count for this machine (capped: scrubbing is
    /// memory-bound well before it is core-bound). Same policy as the
    /// pool size, so "auto" saturates exactly the shared pool.
    pub fn auto_workers() -> usize {
        crate::memory::pool::Pool::default_threads()
    }

    pub fn strategy_name(&self) -> &'static str {
        self.strategy.name()
    }

    pub fn strategy(&self) -> &dyn Protection {
        self.strategy.as_ref()
    }

    pub fn n_weights(&self) -> usize {
        self.image.n
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn shard_states(&self) -> &[ShardState] {
        &self.shards
    }

    /// Byte window `[start, end)` of shard `idx`.
    pub fn shard_range(&self, idx: usize) -> (usize, usize) {
        self.shards[idx].range
    }

    /// The stored image (tests compare it against the monolithic path).
    pub fn image(&self) -> &Encoded {
        &self.image
    }

    /// Mutable access to the stored image for direct manipulation
    /// (tests, custom corruption). Voids the copy-on-write reset
    /// tracking: the next [`ShardedBank::reset`] does a full pristine
    /// restore instead of a touched-blocks-only copy.
    pub fn image_mut(&mut self) -> &mut Encoded {
        self.touched = None;
        &mut self.image
    }

    /// Stored bits (data + check storage) — fault-rate denominator.
    pub fn total_bits(&self) -> u64 {
        self.image.total_bits()
    }

    /// Space overhead actually incurred by the stored image.
    pub fn overhead(&self) -> f64 {
        self.image.oob.len() as f64 / self.image.data.len() as f64
    }

    /// Shard index owning a stored-bit position (data bits first, then
    /// oob bits mapped back through their code block).
    fn shard_of_bit(&self, pos: u64) -> usize {
        let byte = (pos / 8) as usize;
        let data_byte = if byte < self.image.data.len() {
            byte
        } else {
            let opb = self.strategy.oob_bytes_per_block(); // > 0: oob exists
            (byte - self.image.data.len()) / opb * self.strategy.block_bytes()
        };
        self.shards
            .partition_point(|s| s.range.1 <= data_byte)
            .min(self.shards.len() - 1)
    }

    /// Code-block index owning a stored-bit position (oob bits map back
    /// through their block, like `shard_of_bit`) — the grain of the
    /// copy-on-write reset tracking.
    fn block_of_bit(&self, pos: u64) -> usize {
        let byte = (pos / 8) as usize;
        if byte < self.image.data.len() {
            byte / self.strategy.block_bytes()
        } else {
            (byte - self.image.data.len()) / self.strategy.oob_bytes_per_block()
        }
    }

    /// Inject faults at `rate` with the given model and seed; flips the
    /// same bit sequence as the monolithic bank, marks the shards those
    /// bits land in dirty, and records the hit blocks for the
    /// copy-on-write [`ShardedBank::reset`].
    pub fn inject(&mut self, model: FaultModel, rate: f64, seed: u64) -> u64 {
        let mut inj = FaultInjector::new(model, seed);
        let n = FaultInjector::flip_count(self.image.total_bits(), rate);
        let positions = inj.draw_positions(&self.image, n);
        self.inject_positions(&positions)
    }

    /// Flip explicit stored-bit positions (a caller-driven fault
    /// process, e.g. [`crate::memory::fault::Wear`] strikes), with the
    /// same dirty-shard marking and copy-on-write block tracking as
    /// [`ShardedBank::inject`]. Positions must be in-range and should
    /// be distinct — a repeated position flips back. Returns bits
    /// flipped.
    pub fn inject_positions(&mut self, positions: &[u64]) -> u64 {
        let flipped = positions.len() as u64;
        for &pos in positions {
            let shard = self.shard_of_bit(pos);
            let block = self.block_of_bit(pos);
            self.image.flip_bit(pos);
            self.shards[shard].dirty = true;
            if let Some(t) = &mut self.touched {
                // burst-family models emit runs of adjacent bits, so
                // consecutive entries usually repeat one block
                if t.last() != Some(&block) {
                    t.push(block);
                }
            }
        }
        // Past ~1/4 of all *distinct* blocks a full restore beats
        // per-span copies — and a serving bank that injects every epoch
        // but never resets must not grow the log unboundedly. Dedup
        // before judging, so burst models (many flips, few blocks) keep
        // their copy-on-write resets.
        let blocks = self.image.data.len() / self.strategy.block_bytes().max(1);
        let cap = (blocks / 4).max(64);
        if self.touched.as_ref().is_some_and(|t| t.len() > cap) {
            let t = self.touched.as_mut().unwrap();
            t.sort_unstable();
            t.dedup();
            if t.len() > cap {
                self.touched = None;
            }
        }
        self.faults_injected += flipped;
        flipped
    }

    /// Protected read: decode every shard (in parallel) into `out`.
    pub fn read(&mut self, out: &mut [i8]) -> DecodeStats {
        assert_eq!(out.len(), self.image.n);
        let per_shard = decode_shards(
            self.strategy.as_ref(),
            &self.image,
            &ranges_of(&self.shards),
            out,
            self.workers,
        );
        self.merge_pass(&per_shard, false)
    }

    /// Protected read that also reports *which* blocks stayed
    /// detected-uncorrectable: decodes every shard in parallel via the
    /// outcome range APIs, replaces the whole detected-block set (a full
    /// read sees every shard), and returns the aggregate outcome with
    /// absolute block indices.
    pub fn read_outcome(&mut self, out: &mut [i8]) -> DecodeOutcome {
        assert_eq!(out.len(), self.image.n);
        let ranges = ranges_of(&self.shards);
        let strategy = self.strategy.as_ref();
        let image = &self.image;
        let jobs = split_windows(&ranges, out);
        let per_shard = run_jobs(jobs, self.workers, |(i, s, e, win)| {
            (i, strategy.decode_range_outcome(image, s, e, win))
        });
        self.finish_outcome_pass(per_shard, false)
    }

    /// Full scrub pass reporting per-block detections (see
    /// [`ShardedBank::read_outcome`]); replaces the whole detected set.
    pub fn scrub_outcome(&mut self) -> DecodeOutcome {
        let ranges = ranges_of(&self.shards);
        let per_shard = scrub_shards_outcome(
            self.strategy.as_ref(),
            &mut self.image,
            &ranges,
            None,
            self.workers,
        );
        self.finish_outcome_pass(per_shard, true)
    }

    /// [`ShardedBank::scrub_subset`] with per-block detection reporting:
    /// each selected shard's detected-set entry is *replaced* by what
    /// this pass saw (unselected shards keep their recorded detections).
    /// Returns `(shard, outcome)` in sorted shard order regardless of
    /// worker fan-out interleaving.
    pub fn scrub_subset_outcome(&mut self, indices: &[usize]) -> Vec<(usize, DecodeOutcome)> {
        let mut sel: Vec<usize> = indices.to_vec();
        sel.sort_unstable();
        sel.dedup();
        assert!(sel.last().is_none_or(|&i| i < self.shards.len()), "shard index out of range");
        let ranges = ranges_of(&self.shards);
        let per_shard = scrub_shards_outcome(
            self.strategy.as_ref(),
            &mut self.image,
            &ranges,
            Some(&sel),
            self.workers,
        );
        self.finish_outcome_pass(per_shard.clone(), true);
        per_shard
    }

    /// Merge an outcome pass into stats/dirty bookkeeping and the
    /// detected-block set, returning the aggregate outcome.
    fn finish_outcome_pass(
        &mut self,
        per_shard: Vec<(usize, DecodeOutcome)>,
        is_scrub: bool,
    ) -> DecodeOutcome {
        let stats: Vec<(usize, DecodeStats)> =
            per_shard.iter().map(|(i, o)| (*i, o.stats)).collect();
        self.merge_pass(&stats, is_scrub);
        let mut total = DecodeOutcome::default();
        for (idx, outc) in per_shard {
            total.stats.add(&outc.stats);
            for &b in &outc.detected_blocks {
                total.push_detected(b);
            }
            total.overflow |= outc.overflow;
            self.detected_overflow |= outc.overflow;
            if outc.detected_blocks.is_empty() {
                self.detected.remove(&idx);
            } else {
                self.detected.insert(idx, outc.detected_blocks);
            }
        }
        self.enforce_detected_cap();
        total
    }

    /// Keep the bank-wide detected set bounded, flagging the drop.
    fn enforce_detected_cap(&mut self) {
        let mut budget = DETECTED_BLOCK_CAP;
        for list in self.detected.values_mut() {
            if list.len() <= budget {
                budget -= list.len();
            } else {
                list.truncate(budget);
                budget = 0;
                self.detected_overflow = true;
            }
        }
    }

    /// Absolute block indices currently recorded as detected-
    /// uncorrectable (sorted), per the replacement semantics above.
    pub fn detected_blocks(&self) -> Vec<usize> {
        let mut all: Vec<usize> = self.detected.values().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        all
    }

    /// True when detections were dropped at the bank-wide cap.
    pub fn detected_overflow(&self) -> bool {
        self.detected_overflow
    }

    /// Drain the detected-block set for escalation to the recovery tier:
    /// returns `(sorted blocks, overflow)` and clears the record (a
    /// later pass re-detects anything recovery could not fix).
    pub fn take_detected(&mut self) -> (Vec<usize>, bool) {
        let blocks = self.detected_blocks();
        self.detected.clear();
        let ovf = std::mem::take(&mut self.detected_overflow);
        (blocks, ovf)
    }

    /// Write an algebraically recovered block back into the stored
    /// image: re-encode `weights` (length = one block of weights) with
    /// the bank's strategy, store its data/oob bytes at `block`, and
    /// verify the syndrome goes clean. On success the owning shard is
    /// marked dirty (the serving layer must re-ship it — the bytes
    /// changed under it), the block joins the copy-on-write touched log,
    /// and it leaves the detected set.
    pub fn apply_recovery(&mut self, block: usize, weights: &[i8]) -> anyhow::Result<()> {
        let bb = self.strategy.block_bytes();
        let opb = self.strategy.oob_bytes_per_block();
        anyhow::ensure!(weights.len() == bb, "recovered block must be {bb} weights");
        anyhow::ensure!((block + 1) * bb <= self.image.data.len(), "block out of range");
        let enc = self.strategy.encode(weights)?;
        self.image.data[block * bb..(block + 1) * bb].copy_from_slice(&enc.data);
        if opb > 0 {
            self.image.oob[block * opb..(block + 1) * opb].copy_from_slice(&enc.oob);
        }
        let mut check = vec![0i8; bb];
        let outc =
            self.strategy
                .decode_range_outcome(&self.image, block * bb, (block + 1) * bb, &mut check);
        anyhow::ensure!(
            outc.stats.is_clean() && outc.detected_blocks.is_empty(),
            "recovered block {block} does not re-encode to a clean syndrome"
        );
        // direct write: merge_pass's corrected/zeroed rule never sees it,
        // so the dirty + COW bookkeeping is explicit here
        let shard = self
            .shards
            .partition_point(|s| s.range.1 <= block * bb)
            .min(self.shards.len() - 1);
        self.shards[shard].dirty = true;
        if let Some(t) = &mut self.touched {
            if t.last() != Some(&block) {
                t.push(block);
            }
        }
        if let Some(list) = self.detected.get_mut(&shard) {
            list.retain(|&b| b != block);
            if list.is_empty() {
                self.detected.remove(&shard);
            }
        }
        Ok(())
    }

    /// Decode one shard's window into `out` (`out.len()` == window size).
    pub fn read_shard(&mut self, idx: usize, out: &mut [i8]) -> DecodeStats {
        let (s, e) = self.shards[idx].range;
        assert_eq!(out.len(), e - s);
        let stats = self.strategy.decode_range(&self.image, s, e, out);
        self.shards[idx].lifetime.add(&stats);
        self.lifetime.add(&stats);
        stats
    }

    /// Fused decode + dequantize of one shard's window: decodes into the
    /// reusable `scratch` buffer and dequantizes into `out` with the
    /// layer scales that cover the window — the scrub epoch's delta path
    /// (no full-buffer i8 intermediate).
    pub fn decode_dequant_shard(
        &mut self,
        idx: usize,
        layers: &[Layer],
        scratch: &mut Vec<i8>,
        out: &mut [f32],
    ) -> DecodeStats {
        let (s, e) = self.shards[idx].range;
        let stats = crate::quant::decode_dequant_range(
            self.strategy.as_ref(),
            &self.image,
            s,
            e,
            layers,
            scratch,
            out,
        );
        self.shards[idx].lifetime.add(&stats);
        self.lifetime.add(&stats);
        stats
    }

    /// Fused decode + dequantize of *every* shard (fanned out over the
    /// worker pool, one scratch per job) into the full f32 buffer —
    /// the scrub epoch's whole-image refresh path. Same stats
    /// accounting as [`ShardedBank::read`].
    pub fn decode_dequant_all(&mut self, layers: &[Layer], out: &mut [f32]) -> DecodeStats {
        assert_eq!(out.len(), self.image.n);
        let ranges = ranges_of(&self.shards);
        let strategy = self.strategy.as_ref();
        let image = &self.image;
        let jobs = split_windows(&ranges, out);
        let per_shard = run_jobs(jobs, self.workers, |(i, s, e, win)| {
            // decode scratch from the worker's arena, not a fresh Vec —
            // steady-state epochs are allocation-free
            let mut scratch = pool::lease_i8(0);
            let stats = crate::quant::decode_dequant_range(
                strategy,
                image,
                s,
                e,
                layers,
                &mut scratch,
                win,
            );
            (i, stats)
        });
        self.merge_pass(&per_shard, false)
    }

    /// Scrub pass: correct latent errors shard-by-shard in parallel.
    /// Shards whose pass saw any error are marked dirty.
    pub fn scrub(&mut self) -> DecodeStats {
        let ranges = ranges_of(&self.shards);
        let per_shard = scrub_shards(
            self.strategy.as_ref(),
            &mut self.image,
            &ranges,
            None,
            self.workers,
        );
        self.merge_pass(&per_shard, true)
    }

    /// Scrub only the given shards (fanned out over the worker pool),
    /// with the same per-shard stats/dirty accounting as a full
    /// [`ShardedBank::scrub`] — the entry point the adaptive scrub
    /// scheduler drives with its due list. Indices may arrive in any
    /// order and may repeat; each selected shard is scrubbed once.
    /// Returns `(shard, stats)` per scrubbed shard.
    pub fn scrub_subset(&mut self, indices: &[usize]) -> Vec<(usize, DecodeStats)> {
        let mut sel: Vec<usize> = indices.to_vec();
        sel.sort_unstable();
        sel.dedup();
        assert!(sel.last().is_none_or(|&i| i < self.shards.len()), "shard index out of range");
        let ranges = ranges_of(&self.shards);
        let per_shard = scrub_shards(
            self.strategy.as_ref(),
            &mut self.image,
            &ranges,
            Some(&sel),
            self.workers,
        );
        self.merge_pass(&per_shard, true);
        per_shard
    }

    /// Scrub a single shard on the calling thread (no pool fan-out).
    pub fn scrub_shard(&mut self, idx: usize) -> DecodeStats {
        let (s, e) = self.shards[idx].range;
        let stats = self.strategy.scrub_range(&mut self.image, s, e);
        self.merge_pass(&[(idx, stats)], true);
        stats
    }

    /// Stored bits (data + owned check bytes) of shard `idx` — the
    /// denominator of the scheduler's per-shard bit-error rate.
    pub fn shard_bits(&self, idx: usize) -> u64 {
        let (s, e) = self.shards[idx].range;
        let (os, oe) = self.strategy.oob_window(s, e, self.image.data.len(), self.image.oob.len());
        (((e - s) + (oe - os)) * 8) as u64
    }

    /// Indices of dirty shards, clearing the flags.
    pub fn take_dirty(&mut self) -> Vec<usize> {
        let mut dirty = Vec::new();
        for (i, s) in self.shards.iter_mut().enumerate() {
            if s.dirty {
                s.dirty = false;
                dirty.push(i);
            }
        }
        dirty
    }

    /// Reset the image to its pristine (fault-free) state.
    ///
    /// Copy-on-write: only the code blocks hit by fault injection since
    /// the last reset are copied back (a scrub pass only ever writes
    /// inside blocks already carrying a fault — zero-syndrome blocks
    /// are untouched and parity's ragged-tail padding mask is
    /// value-neutral on pristine bytes — so restoring the fault-touched
    /// blocks restores the whole image; the COW-vs-full-reset proptest
    /// pins this down for every fault model). A trial at realistic
    /// rates therefore resets a few hundred bytes, not megabytes. A
    /// direct [`ShardedBank::image_mut`] mutation voids the tracking
    /// and forces a full restore.
    pub fn reset(&mut self) {
        match self.touched.take() {
            Some(mut blocks) => {
                blocks.sort_unstable();
                blocks.dedup();
                let bb = self.strategy.block_bytes();
                let opb = self.strategy.oob_bytes_per_block();
                let (dlen, olen) = (self.image.data.len(), self.image.oob.len());
                for b in blocks {
                    let (lo, hi) = (b * bb, ((b + 1) * bb).min(dlen));
                    self.image.data[lo..hi].copy_from_slice(&self.pristine.data[lo..hi]);
                    if opb > 0 {
                        let (ol, oh) = (b * opb, ((b + 1) * opb).min(olen));
                        self.image.oob[ol..oh].copy_from_slice(&self.pristine.oob[ol..oh]);
                    }
                }
            }
            None => {
                self.image.data.copy_from_slice(&self.pristine.data);
                self.image.oob.copy_from_slice(&self.pristine.oob);
            }
        }
        self.touched = Some(Vec::new());
        self.detected.clear();
        self.detected_overflow = false;
        for s in &mut self.shards {
            s.dirty = false;
            s.last_scrub = DecodeStats::default();
        }
    }

    fn merge_pass(&mut self, per_shard: &[(usize, DecodeStats)], is_scrub: bool) -> DecodeStats {
        let mut total = DecodeStats::default();
        for &(idx, stats) in per_shard {
            total.add(&stats);
            let shard = &mut self.shards[idx];
            shard.lifetime.add(&stats);
            if is_scrub {
                shard.last_scrub = stats;
                shard.scrubs += 1;
                // Dirty only when the pass *modified* stored bytes
                // (corrected / zeroed). Detected-but-uncorrectable
                // blocks leave the image as stored — decode output is
                // unchanged, so re-shipping the shard every epoch would
                // send identical deltas forever.
                if stats.corrected + stats.zeroed > 0 {
                    shard.dirty = true;
                }
            }
        }
        self.lifetime.add(&total);
        total
    }
}

fn ranges_of(shards: &[ShardState]) -> Vec<(usize, usize)> {
    shards.iter().map(|s| s.range).collect()
}

/// Split `buf` into disjoint per-shard `&mut` windows following
/// `ranges` (which must tile `[0, buf.len())` in order); yields
/// `(shard_idx, start, end, window)` jobs for the worker pool.
fn split_windows<'a, T>(
    ranges: &[(usize, usize)],
    buf: &'a mut [T],
) -> Vec<(usize, usize, usize, &'a mut [T])> {
    let mut jobs = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    let mut off = 0usize;
    for (i, &(s, e)) in ranges.iter().enumerate() {
        debug_assert_eq!(s, off);
        let (win, next) = rest.split_at_mut(e - s);
        jobs.push((i, s, e, win));
        rest = next;
        off = e;
    }
    jobs
}

/// Decode every shard window of `image` into the matching window of
/// `out`, in parallel; returns per-shard stats.
fn decode_shards(
    strategy: &dyn Protection,
    image: &Encoded,
    ranges: &[(usize, usize)],
    out: &mut [i8],
    workers: usize,
) -> Vec<(usize, DecodeStats)> {
    let jobs = split_windows(ranges, out);
    run_jobs(jobs, workers, |(i, s, e, win)| {
        (i, strategy.decode_range(image, s, e, win))
    })
}

/// Scrub shard windows of `image` in place, in parallel: the data and
/// oob byte ranges of distinct shards are disjoint, so the stored image
/// is split into per-shard &mut spans handed to the workers. With
/// `selected` (sorted, deduped) only those shards get jobs — the walk
/// still advances through every range so the spans line up.
fn scrub_shards(
    strategy: &dyn Protection,
    image: &mut Encoded,
    ranges: &[(usize, usize)],
    selected: Option<&[usize]>,
    workers: usize,
) -> Vec<(usize, DecodeStats)> {
    let (data_len, oob_len) = (image.data.len(), image.oob.len());
    let mut jobs = Vec::with_capacity(selected.map_or(ranges.len(), <[usize]>::len));
    let mut d_rest: &mut [u8] = &mut image.data;
    let mut o_rest: &mut [u8] = &mut image.oob;
    let (mut d_off, mut o_off) = (0usize, 0usize);
    for (i, &(s, e)) in ranges.iter().enumerate() {
        debug_assert_eq!(s, d_off);
        let (os, oe) = strategy.oob_window(s, e, data_len, oob_len);
        debug_assert_eq!(os, o_off);
        let (d_win, d_next) = d_rest.split_at_mut(e - d_off);
        let (o_win, o_next) = o_rest.split_at_mut(oe - o_off);
        if selected.is_none_or(|sel| sel.binary_search(&i).is_ok()) {
            jobs.push((i, d_win, o_win));
        }
        d_rest = d_next;
        o_rest = o_next;
        d_off = e;
        o_off = oe;
    }
    run_jobs(jobs, workers, |(i, d_win, o_win)| {
        // tiled form: the worker walks 64-block tiles, the word-parallel
        // clean proof makes a fault-free shard scrub a read-only pass
        (i, strategy.scrub_span_tiled(d_win, o_win))
    })
}

/// Outcome-reporting variant of [`scrub_shards`]: identical span split
/// and fan-out, but each job runs `scrub_span_outcome` with the shard's
/// starting block as the base, so the per-shard detected-block lists
/// carry *absolute* image-wide indices. `run_jobs` returns results in
/// submission (sorted shard) order, independent of worker interleaving.
fn scrub_shards_outcome(
    strategy: &dyn Protection,
    image: &mut Encoded,
    ranges: &[(usize, usize)],
    selected: Option<&[usize]>,
    workers: usize,
) -> Vec<(usize, DecodeOutcome)> {
    let (data_len, oob_len) = (image.data.len(), image.oob.len());
    let block = strategy.block_bytes().max(1);
    let mut jobs = Vec::with_capacity(selected.map_or(ranges.len(), <[usize]>::len));
    let mut d_rest: &mut [u8] = &mut image.data;
    let mut o_rest: &mut [u8] = &mut image.oob;
    let (mut d_off, mut o_off) = (0usize, 0usize);
    for (i, &(s, e)) in ranges.iter().enumerate() {
        debug_assert_eq!(s, d_off);
        let (os, oe) = strategy.oob_window(s, e, data_len, oob_len);
        debug_assert_eq!(os, o_off);
        let (d_win, d_next) = d_rest.split_at_mut(e - d_off);
        let (o_win, o_next) = o_rest.split_at_mut(oe - o_off);
        if selected.is_none_or(|sel| sel.binary_search(&i).is_ok()) {
            jobs.push((i, s / block, d_win, o_win));
        }
        d_rest = d_next;
        o_rest = o_next;
        d_off = e;
        o_off = oe;
    }
    run_jobs(jobs, workers, |(i, base, d_win, o_win)| {
        (i, strategy.scrub_span_outcome(d_win, o_win, base))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecc::strategy_by_name;
    use crate::memory::MemoryBank;
    use crate::util::rng::Rng;

    fn wot_weights(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                if i % 8 == 7 {
                    (rng.below(256) as i64 - 128) as i8
                } else {
                    (rng.below(128) as i64 - 64) as i8
                }
            })
            .collect()
    }

    #[test]
    fn plan_tiles_and_aligns() {
        // 7 blocks of 8 bytes over 3 shards: 3 + 3 + 1 blocks.
        assert_eq!(
            plan_shards(56, 8, 3),
            vec![(0, 24), (24, 48), (48, 56)]
        );
        // more shards than blocks collapses to one shard per block
        assert_eq!(plan_shards(16, 8, 64), vec![(0, 8), (8, 16)]);
        // byte-granular code with a ragged tail
        assert_eq!(plan_shards(10, 1, 4), vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
        // empty image still yields one (empty) shard
        assert_eq!(plan_shards(0, 8, 4), vec![(0, 0)]);
    }

    #[test]
    fn sharded_matches_monolithic_decode_and_scrub() {
        let w = wot_weights(8 * 56, 3);
        for name in ["faulty", "zero", "ecc", "in-place"] {
            for shards in [1usize, 2, 7, 64] {
                for workers in [1usize, 4] {
                    let mut mono =
                        MemoryBank::new(strategy_by_name(name).unwrap(), &w).unwrap();
                    let mut sb = ShardedBank::new(
                        strategy_by_name(name).unwrap(),
                        &w,
                        shards,
                        workers,
                    )
                    .unwrap();
                    assert_eq!(mono.total_bits(), sb.total_bits());
                    mono.inject(FaultModel::Uniform, 2e-3, 99);
                    sb.inject(FaultModel::Uniform, 2e-3, 99);
                    let mut a = vec![0i8; w.len()];
                    let mut b = vec![0i8; w.len()];
                    let sa = mono.read(&mut a);
                    let sb_stats = sb.read(&mut b);
                    assert_eq!(a, b, "{name} x{shards} w{workers}: decode");
                    assert_eq!(sa, sb_stats, "{name} x{shards} w{workers}: stats");
                    let sc_a = mono.scrub();
                    let sc_b = sb.scrub();
                    assert_eq!(sc_a, sc_b, "{name} x{shards} w{workers}: scrub stats");
                    assert_eq!(
                        mono.image().data,
                        sb.image().data,
                        "{name} x{shards} w{workers}: scrubbed data"
                    );
                    assert_eq!(mono.image().oob, sb.image().oob);
                }
            }
        }
    }

    #[test]
    fn injection_marks_hit_shards_dirty() {
        let w = wot_weights(1024, 5);
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w, 8, 2).unwrap();
        assert!(sb.take_dirty().is_empty(), "fresh bank must be clean");
        sb.inject(FaultModel::Uniform, 1e-3, 7);
        let dirty = sb.take_dirty();
        assert!(!dirty.is_empty());
        // flags are consumed
        assert!(sb.take_dirty().is_empty());
        // a scrub that corrects something re-marks exactly the hit shard
        sb.reset();
        sb.image_mut().flip_bit(5); // one data-bit flip, lands in shard 0
        let stats = sb.scrub();
        assert_eq!(stats.corrected, 1);
        assert_eq!(sb.take_dirty(), vec![0]);
        // and a scrub over the healed image marks nothing
        let stats = sb.scrub();
        assert!(stats.is_clean());
        assert!(sb.take_dirty().is_empty());
    }

    #[test]
    fn oob_faults_mark_owning_shard() {
        // ecc: every oob byte belongs to one 8-byte block; flipping only
        // oob bits must still dirty exactly the owning shards.
        let w = wot_weights(512, 6);
        let mut sb = ShardedBank::new(strategy_by_name("ecc").unwrap(), &w, 4, 1).unwrap();
        let data_bits = 512 * 8;
        // oob byte 0 -> block 0 -> shard 0; last oob byte -> last shard
        sb.image_mut().flip_bit(data_bits);
        sb.shards[sb.shard_of_bit(data_bits)].dirty = true;
        let last = sb.total_bits() - 1;
        let idx = sb.shard_of_bit(last);
        assert_eq!(idx, sb.num_shards() - 1);
        assert_eq!(sb.shard_of_bit(data_bits), 0);
    }

    #[test]
    fn reset_restores_pristine() {
        let w = wot_weights(256, 9);
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w, 4, 2).unwrap();
        sb.inject(FaultModel::Uniform, 1e-2, 3);
        sb.reset();
        let mut out = vec![0i8; w.len()];
        let stats = sb.read(&mut out);
        assert_eq!(out, w);
        assert_eq!(stats.corrected + stats.detected, 0);
        assert!(sb.take_dirty().is_empty());
    }

    #[test]
    fn cow_reset_restores_after_inject_and_scrub() {
        // Scrub modifies stored bytes (corrections, parity zeroing) —
        // but only inside fault-touched blocks, so the COW reset must
        // still restore the exact pristine image. Ragged tail included.
        let w = wot_weights(8 * 37, 15);
        for name in ["faulty", "zero", "ecc", "in-place"] {
            let pristine = ShardedBank::new(strategy_by_name(name).unwrap(), &w, 5, 2).unwrap();
            let mut sb = ShardedBank::new(strategy_by_name(name).unwrap(), &w, 5, 2).unwrap();
            sb.inject(FaultModel::Burst { len: 3 }, 5e-3, 21);
            sb.scrub();
            sb.inject(FaultModel::Uniform, 1e-3, 22); // touched spans accumulate
            sb.reset();
            assert_eq!(sb.image().data, pristine.image().data, "{name}: data residue");
            assert_eq!(sb.image().oob, pristine.image().oob, "{name}: oob residue");
            assert!(sb.take_dirty().is_empty(), "{name}");
        }
    }

    #[test]
    fn direct_image_mutation_falls_back_to_full_restore() {
        let w = wot_weights(512, 23);
        let mut sb = ShardedBank::new(strategy_by_name("ecc").unwrap(), &w, 4, 2).unwrap();
        // an untracked mutation: COW bookkeeping cannot see it...
        sb.image_mut().data[100] ^= 0xFF;
        sb.image_mut().oob[3] ^= 0x10;
        // ...so reset must restore everything anyway
        sb.reset();
        let fresh = ShardedBank::new(strategy_by_name("ecc").unwrap(), &w, 4, 2).unwrap();
        assert_eq!(sb.image().data, fresh.image().data);
        assert_eq!(sb.image().oob, fresh.image().oob);
    }

    #[test]
    fn decode_dequant_all_matches_read_plus_dequant() {
        use crate::model::manifest::Layer;
        use crate::quant::dequantize_into;
        let w = wot_weights(8 * 200, 41);
        let layers = vec![Layer {
            name: "w".into(),
            shape: vec![w.len()],
            offset: 0,
            size: w.len(),
            scale: 0.05,
            scale_prewot: 0.05,
        }];
        let mut sb =
            ShardedBank::new(strategy_by_name("in-place").unwrap(), &w, 7, 3).unwrap();
        sb.inject(FaultModel::Uniform, 1e-3, 9);
        // reference: parallel decode, then a separate dequantize pass
        let mut q = vec![0i8; w.len()];
        let read_stats = sb.read(&mut q);
        let mut want = vec![0f32; w.len()];
        dequantize_into(&q, &layers, &mut want);
        // fused parallel path must agree on values and stats
        let mut got = vec![0f32; w.len()];
        let fused_stats = sb.decode_dequant_all(&layers, &mut got);
        assert_eq!(got, want);
        assert_eq!(fused_stats, read_stats);
    }

    #[test]
    fn scrub_subset_touches_only_selected_shards() {
        let w = wot_weights(8 * 64, 33);
        for name in ["zero", "ecc", "in-place"] {
            let mk = || ShardedBank::new(strategy_by_name(name).unwrap(), &w, 8, 2).unwrap();
            let mut full = mk();
            let mut sub = mk();
            full.inject(FaultModel::Uniform, 2e-3, 51);
            sub.inject(FaultModel::Uniform, 2e-3, 51);
            full.take_dirty();
            sub.take_dirty();
            // unsorted, duplicated input: each shard scrubbed once
            let per = sub.scrub_subset(&[5, 1, 5, 3]);
            assert_eq!(
                per.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
                vec![1, 3, 5],
                "{name}: selection must be sorted and deduped"
            );
            // selected shards match what a full scrub does to them...
            full.scrub();
            for &(i, stats) in &per {
                assert_eq!(stats, full.shard_states()[i].last_scrub, "{name}: shard {i}");
                let (s, e) = sub.shard_range(i);
                assert_eq!(
                    sub.image().data[s..e],
                    full.image().data[s..e],
                    "{name}: shard {i} bytes"
                );
            }
            // ...unselected shards keep their (possibly faulty) bytes
            let mut pristine = mk();
            pristine.inject(FaultModel::Uniform, 2e-3, 51);
            for i in [0usize, 2, 4, 6, 7] {
                let (s, e) = sub.shard_range(i);
                assert_eq!(
                    sub.image().data[s..e],
                    pristine.image().data[s..e],
                    "{name}: unselected shard {i} must be untouched"
                );
                assert_eq!(sub.shard_states()[i].scrubs, 0, "{name}: shard {i}");
            }
            // dirty flags: only selected shards whose pass modified bytes
            for i in sub.take_dirty() {
                assert!([1usize, 3, 5].contains(&i), "{name}: dirty {i}");
            }
        }
    }

    #[test]
    fn scrub_shard_matches_subset_of_one() {
        let w = wot_weights(8 * 40, 35);
        let mut a = ShardedBank::new(strategy_by_name("in-place").unwrap(), &w, 5, 2).unwrap();
        let mut b = ShardedBank::new(strategy_by_name("in-place").unwrap(), &w, 5, 2).unwrap();
        a.inject(FaultModel::Burst { len: 3 }, 3e-3, 77);
        b.inject(FaultModel::Burst { len: 3 }, 3e-3, 77);
        for idx in 0..a.num_shards() {
            let sa = a.scrub_shard(idx);
            let sb = b.scrub_subset(&[idx]);
            assert_eq!(sb, vec![(idx, sa)]);
        }
        assert_eq!(a.image().data, b.image().data);
        assert_eq!(a.lifetime, b.lifetime);
    }

    #[test]
    fn shard_bits_sum_to_total() {
        let w = wot_weights(8 * 56, 37);
        for name in ["faulty", "zero", "ecc", "in-place"] {
            let sb = ShardedBank::new(strategy_by_name(name).unwrap(), &w, 7, 1).unwrap();
            let sum: u64 = (0..sb.num_shards()).map(|i| sb.shard_bits(i)).sum();
            assert_eq!(sum, sb.total_bits(), "{name}");
        }
    }

    #[test]
    fn per_shard_stats_sum_to_lifetime() {
        let w = wot_weights(2048, 11);
        let mut sb = ShardedBank::new(strategy_by_name("ecc").unwrap(), &w, 7, 3).unwrap();
        sb.inject(FaultModel::Uniform, 1e-3, 13);
        let mut out = vec![0i8; w.len()];
        sb.read(&mut out);
        sb.scrub();
        let mut sum = DecodeStats::default();
        for s in sb.shard_states() {
            sum.add(&s.lifetime);
        }
        assert_eq!(sum, sb.lifetime);
        assert!(sb.shard_states().iter().all(|s| s.scrubs == 1));
    }

    #[test]
    fn detected_blocks_survive_scrub_subset_fanout() {
        // regression: per-shard stats used to lose *which* blocks were
        // uncorrectable. Indices must come back absolute and in sorted
        // shard order even when the worker pool interleaves the jobs.
        let w = wot_weights(8 * 64, 61);
        let mut sb = ShardedBank::new(strategy_by_name("ecc").unwrap(), &w, 8, 4).unwrap();
        // 8 shards x 8 blocks; double-flip blocks 9 (shard 1), 26
        // (shard 3), 44 and 45 (shard 5) — uncorrectable for SEC-DED
        let victims = [9u64, 26, 44, 45];
        for &b in &victims {
            sb.image_mut().flip_bit(b * 64 + 2);
            sb.image_mut().flip_bit(b * 64 + 11);
        }
        let per = sb.scrub_subset_outcome(&[5, 1, 5, 3]);
        assert_eq!(
            per.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
            vec![1, 3, 5],
            "fan-out must not reorder the per-shard results"
        );
        assert_eq!(per[0].1.detected_blocks, [9], "shard 1");
        assert_eq!(per[1].1.detected_blocks, [26], "shard 3");
        assert_eq!(per[2].1.detected_blocks, [44, 45], "shard 5");
        assert_eq!(sb.detected_blocks(), vec![9, 26, 44, 45]);
        assert!(!sb.detected_overflow());
        // replacement semantics: heal block 9 and re-scrub only shard 1
        // — its entry is replaced by the now-clean pass, others persist
        sb.image_mut().flip_bit(9 * 64 + 2);
        sb.image_mut().flip_bit(9 * 64 + 11);
        let per = sb.scrub_subset_outcome(&[1]);
        assert!(per[0].1.detected_blocks.is_empty());
        assert_eq!(sb.detected_blocks(), vec![26, 44, 45]);
        // a full read replaces the whole set
        let mut out = vec![0i8; w.len()];
        let outc = sb.read_outcome(&mut out);
        assert_eq!(outc.detected_blocks, vec![26, 44, 45]);
        assert_eq!(sb.detected_blocks(), vec![26, 44, 45]);
    }

    #[test]
    fn apply_recovery_reencodes_clean_and_marks_dirty() {
        let w = wot_weights(8 * 32, 63);
        for name in ["milr", "ecc", "in-place"] {
            let mut sb = ShardedBank::new(strategy_by_name(name).unwrap(), &w, 4, 2).unwrap();
            // corrupt block 4 beyond correction
            if name == "milr" {
                sb.image_mut().flip_bit(4 * 64 + 6); // WOT-breaking bit6 flip
            } else {
                sb.image_mut().flip_bit(4 * 64 + 2);
                sb.image_mut().flip_bit(4 * 64 + 11);
            }
            let mut out = vec![0i8; w.len()];
            let outc = sb.read_outcome(&mut out);
            assert_eq!(outc.detected_blocks, [4], "{name}: corruption detected");
            sb.take_dirty();
            // recovery hands back the true weights of the block
            sb.apply_recovery(4, &w[4 * 8..5 * 8]).unwrap();
            assert!(sb.detected_blocks().is_empty(), "{name}: block leaves the set");
            assert_eq!(sb.take_dirty(), vec![0], "{name}: owning shard re-ships");
            let outc = sb.read_outcome(&mut out);
            assert!(outc.stats.is_clean(), "{name}: syndrome clean after recovery");
            assert_eq!(out, w, "{name}: recovered weights are served");
        }
    }

    #[test]
    fn apply_recovery_rejects_bad_blocks() {
        let w = wot_weights(8 * 16, 65);
        let mut sb = ShardedBank::new(strategy_by_name("milr").unwrap(), &w, 2, 1).unwrap();
        // non-WOT "recovered" values cannot re-encode to a clean probe
        let bad = [100i8, 0, 0, 0, 0, 0, 0, 0];
        assert!(sb.apply_recovery(3, &bad).is_err());
        assert!(sb.apply_recovery(0, &w[..4]).is_err(), "wrong length");
        assert!(sb.apply_recovery(999, &w[..8]).is_err(), "out of range");
    }
}
