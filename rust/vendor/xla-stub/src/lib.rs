//! Stub of the PJRT-backed `xla` crate used by `zsecc::runtime`.
//!
//! The offline build environment has no PJRT plugin and no registry access,
//! so this crate provides the exact type/method surface `zsecc` compiles
//! against; every entry point that would touch PJRT returns [`XlaError`].
//! All artifact-gated tests and harness paths detect the failure (or the
//! missing `artifacts/index.json` first) and skip gracefully. To run real
//! models, replace the `xla` path dependency in `rust/Cargo.toml` with the
//! real crate — the signatures below mirror it.

/// Error for every stubbed PJRT operation; rendered with `{:?}` upstream.
pub struct XlaError(pub String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} unavailable (built against the vendored xla stub; \
         link the real PJRT-backed xla crate to execute models)"
    ))
}

pub struct PjRtClient;
pub struct PjRtDevice;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compilation"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(unavailable("host-to-device transfer"))
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable("HLO text parsing"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("execution"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("device-to-host transfer"))
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable("literal untupling"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable("literal conversion"))
    }
}
