//! Offline shim for the `anyhow` crate: just enough of the API surface for
//! this workspace (the registry is unreachable in the build environment).
//!
//! Provides `anyhow::Error`, `anyhow::Result`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Like the real crate, `Error` deliberately does NOT
//! implement `std::error::Error`, so the blanket `From<E: std::error::Error>`
//! conversion and `?`-propagation of `Error` itself (via the reflexive
//! `From<T> for T`) can coexist.

use std::fmt;

pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything displayable (mirror of `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Root cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_deref().map(|e| e as _);
        std::iter::from_fn(move || {
            let e = cur?;
            cur = e.source();
            Some(e)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("fmt", args...)` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `bail!(...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, ...)` — `bail!` unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7);
    }

    fn propagates() -> Result<u32> {
        fails()?;
        Ok(1)
    }

    fn from_std() -> Result<u32> {
        let n: u32 = "not a number".parse()?;
        Ok(n)
    }

    #[test]
    fn macros_and_propagation() {
        assert_eq!(propagates().unwrap_err().to_string(), "boom 7");
        assert!(from_std().is_err());
        let e: Error = anyhow!("x={}", 3);
        assert_eq!(format!("{e}"), "x=3");
        let r: Result<()> = (|| {
            ensure!(1 + 1 == 3, "math is broken: {}", 2);
            Ok(())
        })();
        assert_eq!(r.unwrap_err().to_string(), "math is broken: 2");
    }
}
