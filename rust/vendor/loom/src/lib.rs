//! Offline shim of the `loom` permutation-testing API.
//!
//! The real `loom` crate model-checks concurrent code by running a test
//! body many times under a deterministic scheduler that explores every
//! bounded thread interleaving (DPOR). This repo builds fully offline,
//! so this shim provides the same *API surface* with a weaker — but
//! still adversarial — exploration strategy: the body runs for many
//! iterations on real OS threads, and every atomic operation routed
//! through [`sync::atomic`] first calls a preemption hook that
//! pseudo-randomly yields or briefly sleeps, perturbing the schedule
//! around exactly the operations where interleaving matters. Each
//! iteration reseeds the perturbation stream, so repeated runs walk
//! different schedules.
//!
//! Tests written against this shim therefore must assert *invariants*
//! (exactly-once delivery, conserved counts, a single seal winner) that
//! hold under every schedule — the same discipline real loom enforces —
//! and they keep compiling unchanged if the real crate is swapped in
//! (`loom = "0.7"` in place of the vendored path) for exhaustive
//! checking on a networked machine.
//!
//! Knobs: `LOOM_ITERS` (iterations per [`model`] call, default 200) and
//! `LOOM_PREEMPT_BOUND` (accepted for CLI compatibility; the shim's
//! exploration is already bounded by its iteration count).

use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};

/// Global perturbation state: a splitmix-style counter shared by every
/// thread of the current iteration. Interleaved increments from many
/// threads are welcome — they add genuine nondeterminism on top of the
/// per-iteration reseed.
static SCHED_STATE: StdAtomicU64 = StdAtomicU64::new(0x9e3779b97f4a7c15);

/// Pseudo-randomly perturb the current thread's schedule. Called by
/// every shimmed atomic operation.
pub(crate) fn preempt() {
    let x = SCHED_STATE.fetch_add(0x9e3779b97f4a7c15, StdOrdering::Relaxed);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^= z >> 31;
    match z & 0x3f {
        // ~1/8 of atomic ops give up the timeslice entirely,
        0..=7 => std::thread::yield_now(),
        // ~1/32 park long enough for a cross-core preemption,
        8..=9 => std::thread::sleep(std::time::Duration::from_micros(z % 50)),
        // the rest run straight through (the common schedule).
        _ => {}
    }
}

/// Run `f` under bounded schedule exploration: `LOOM_ITERS` iterations
/// (default 200), each with a reseeded perturbation stream.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let iters: u64 = std::env::var("LOOM_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    for i in 0..iters {
        SCHED_STATE.store(
            0x9e3779b97f4a7c15u64.wrapping_mul(i.wrapping_add(1)),
            StdOrdering::Relaxed,
        );
        f();
    }
}

pub mod thread {
    //! Real-thread mirrors of `loom::thread`.

    pub use std::thread::{JoinHandle, Result};

    /// Spawn a real OS thread (the shim explores schedules via the
    /// atomic-op preemption hook, not a virtual scheduler).
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::spawn(f)
    }

    pub fn yield_now() {
        std::thread::yield_now();
    }
}

pub mod cell {
    //! `loom::cell::UnsafeCell`: closure-scoped raw-pointer access, so
    //! code written for loom's access-tracking cell compiles against
    //! both the shim and the real crate.

    #[derive(Debug, Default)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        pub fn new(v: T) -> Self {
            UnsafeCell(std::cell::UnsafeCell::new(v))
        }

        /// Immutable access. Safety contract is the caller's, exactly as
        /// with `std::cell::UnsafeCell::get`.
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Mutable access. Safety contract is the caller's.
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}

pub mod sync {
    //! `loom::sync`: std primitives, with atomics wrapped to call the
    //! preemption hook around every operation.

    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        /// Declare one shimmed atomic wrapper type: every operation
        /// calls [`crate::preempt`] first, then delegates to std.
        macro_rules! shim_atomic {
            ($name:ident, $std:ty, $val:ty) => {
                #[derive(Debug, Default)]
                pub struct $name($std);

                impl $name {
                    pub fn new(v: $val) -> Self {
                        Self(<$std>::new(v))
                    }

                    pub fn load(&self, o: Ordering) -> $val {
                        crate::preempt();
                        self.0.load(o)
                    }

                    pub fn store(&self, v: $val, o: Ordering) {
                        crate::preempt();
                        self.0.store(v, o)
                    }

                    pub fn swap(&self, v: $val, o: Ordering) -> $val {
                        crate::preempt();
                        self.0.swap(v, o)
                    }

                    pub fn fetch_add(&self, v: $val, o: Ordering) -> $val {
                        crate::preempt();
                        self.0.fetch_add(v, o)
                    }

                    pub fn fetch_sub(&self, v: $val, o: Ordering) -> $val {
                        crate::preempt();
                        self.0.fetch_sub(v, o)
                    }

                    pub fn fetch_max(&self, v: $val, o: Ordering) -> $val {
                        crate::preempt();
                        self.0.fetch_max(v, o)
                    }

                    pub fn compare_exchange(
                        &self,
                        cur: $val,
                        new: $val,
                        ok: Ordering,
                        err: Ordering,
                    ) -> Result<$val, $val> {
                        crate::preempt();
                        self.0.compare_exchange(cur, new, ok, err)
                    }

                    pub fn compare_exchange_weak(
                        &self,
                        cur: $val,
                        new: $val,
                        ok: Ordering,
                        err: Ordering,
                    ) -> Result<$val, $val> {
                        crate::preempt();
                        self.0.compare_exchange_weak(cur, new, ok, err)
                    }
                }
            };
        }

        shim_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
        shim_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        shim_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

        /// `AtomicBool` has a different value type; declared by hand
        /// (fetch_add/sub/max don't exist on bools).
        #[derive(Debug, Default)]
        pub struct AtomicBool(std::sync::atomic::AtomicBool);

        impl AtomicBool {
            pub fn new(v: bool) -> Self {
                Self(std::sync::atomic::AtomicBool::new(v))
            }

            pub fn load(&self, o: Ordering) -> bool {
                crate::preempt();
                self.0.load(o)
            }

            pub fn store(&self, v: bool, o: Ordering) {
                crate::preempt();
                self.0.store(v, o)
            }

            pub fn swap(&self, v: bool, o: Ordering) -> bool {
                crate::preempt();
                self.0.swap(v, o)
            }
        }

        pub fn fence(o: Ordering) {
            crate::preempt();
            std::sync::atomic::fence(o)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn model_reruns_and_atomics_count() {
        std::env::set_var("LOOM_ITERS", "8");
        let runs = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let r = runs.clone();
        super::model(move || {
            let c = Arc::new(AtomicU64::new(0));
            let c2 = c.clone();
            let h = super::thread::spawn(move || {
                c2.fetch_add(1, Ordering::SeqCst);
            });
            c.fetch_add(1, Ordering::SeqCst);
            h.join().unwrap();
            assert_eq!(c.load(Ordering::SeqCst), 2);
            r.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(runs.load(std::sync::atomic::Ordering::SeqCst), 8);
    }
}
