//! Inspect the WOT training artifacts of one model: Table-1 row, Fig-1
//! large-weight position histogram (pre vs post WOT), and the Fig-3 /
//! Fig-4 training curves, all rendered as ASCII.
//!
//! Run: `cargo run --release --example wot_inspect -- --model vgg16_s`

use zsecc::harness::{fig1, fig34, table1};
use zsecc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let artifacts = zsecc::artifacts_dir();
    let model = args.str_or("model", "squeezenet_s");
    let models = vec![model.clone()];

    let rows = table1::run(&artifacts, &models, false)?;
    println!("{}", table1::render(&rows));

    let figs = fig1::run(&artifacts, &models)?;
    println!("{}", fig1::render(&figs));
    for f in &figs {
        println!(
            "pre-WOT large-position uniformity (tol 50%): {}",
            fig1::is_roughly_uniform(&f.pre_wot, 0.5)
        );
    }

    let logs = fig34::run(&artifacts, &models)?;
    println!("{}", fig34::render_fig3(&logs));
    println!("{}", fig34::render_fig4(&logs));
    for (name, ok) in fig34::shape_checks(&logs) {
        println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
    }
    Ok(())
}
