//! Quickstart: the full three-layer stack on one model, end to end.
//!
//!   1. load the AOT artifacts (manifest + int8 weights + HLO);
//!   2. encode the weights with in-place zero-space ECC (0% overhead);
//!   3. inject memory faults, decode (single-bit errors corrected);
//!   4. run inference through PJRT and compare accuracy:
//!      fault-free vs protected-under-faults vs unprotected-under-faults;
//!   5. cross-check the Pallas-kernel HLO variant against the fast one.
//!
//! Run: `cargo run --release --example quickstart [-- --model squeezenet_s]`
//! (requires `make artifacts` first).

use std::sync::Arc;

use zsecc::ecc::strategy_by_name;
use zsecc::harness::eval::cell_seed;
use zsecc::memory::{FaultModel, MemoryBank};
use zsecc::model::{load_weights, EvalSet, Manifest};
use zsecc::quant::{dequantize_into, wot_violations};
use zsecc::runtime::{accuracy, Runtime};
use zsecc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let artifacts = zsecc::artifacts_dir();
    let model = args.str_or("model", "squeezenet_s");
    let rate = args.f64_or("rate", 1e-3)?;
    println!("== zsecc quickstart: {model} from {} ==", artifacts.display());

    // ---- 1. artifacts ------------------------------------------------
    let man = Manifest::load_model(&artifacts, &model)?;
    let weights = load_weights(&man.weights_path(), man.num_weights)?;
    println!(
        "loaded {} int8 weights across {} protected tensors (python-side wot_acc={:.4})",
        man.num_weights,
        man.layers.len(),
        man.wot_acc
    );
    assert_eq!(wot_violations(&weights), 0, "WOT constraint must hold");

    // ---- 2. zero-space encode ----------------------------------------
    let strat = strategy_by_name("in-place")?;
    let mut bank = MemoryBank::new(strat, &weights)?;
    println!(
        "in-place ECC stored image: {} bits, overhead {:.1}% (SEC-DED strength)",
        bank.total_bits(),
        bank.overhead() * 100.0
    );

    // ---- 3. fault injection + protected read --------------------------
    let n = bank.inject(FaultModel::Uniform, rate, cell_seed(&model, "demo", rate, 0));
    let mut protected = vec![0i8; weights.len()];
    let stats = bank.read(&mut protected);
    println!(
        "injected {n} bit flips at rate {rate:.0e}: corrected {} blocks, {} uncorrectable",
        stats.corrected, stats.detected
    );

    // unprotected comparison: same number of flips straight into weights
    let mut unprot_bank =
        MemoryBank::new(strategy_by_name("faulty")?, &weights)?;
    unprot_bank.inject(FaultModel::Uniform, rate, cell_seed(&model, "demo", rate, 0));
    let mut unprotected = vec![0i8; weights.len()];
    unprot_bank.read(&mut unprotected);

    // ---- 4. PJRT inference -------------------------------------------
    let rt = Runtime::cpu()?;
    let ds = Arc::new(EvalSet::load(&artifacts.join("dataset.eval.bin"))?);
    let batch = *man.batches.iter().max().unwrap();
    let exe = rt.load_model(&man, batch)?;
    let mut f = vec![0f32; weights.len()];
    let acc_of = |rt: &Runtime, exe: &zsecc::runtime::Executable, q: &[i8], f: &mut Vec<f32>| -> anyhow::Result<f64> {
        dequantize_into(q, &man.layers, f);
        let wb = rt.bind_weights(f)?;
        accuracy(rt, exe, &wb, &ds)
    };
    let base = acc_of(&rt, &exe, &weights, &mut f)?;
    let prot = acc_of(&rt, &exe, &protected, &mut f)?;
    let faulty = acc_of(&rt, &exe, &unprotected, &mut f)?;
    println!("accuracy: fault-free={base:.4}  in-place-protected={prot:.4}  unprotected={faulty:.4}");
    println!(
        "accuracy drop: protected {:.2} pts vs unprotected {:.2} pts",
        (base - prot) * 100.0,
        (base - faulty) * 100.0
    );

    // ---- 5. L1 Pallas variant cross-check ------------------------------
    let pb = man.pallas_batch;
    let exe_pallas = rt.load(&man.hlo_pallas_path(pb)?, pb, &man)?;
    let exe_fast = rt.load_model(&man, pb)?;
    dequantize_into(&weights, &man.layers, &mut f);
    let wb = rt.bind_weights(&f)?;
    let imgs = ds.batch(0, pb);
    let a = exe_fast.run(&rt, &wb, imgs)?;
    let b = exe_pallas.run(&rt, &wb, imgs)?;
    let max_diff = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    println!("pallas-vs-fast logits max |diff| = {max_diff:.2e} over a {pb}-image batch");
    anyhow::ensure!(max_diff < 1e-3, "pallas variant diverged from fast variant");
    println!("quickstart OK");
    Ok(())
}
