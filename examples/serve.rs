//! Protected inference serving: the end-to-end systems driver.
//!
//! Starts the coordinator (dynamic batcher + inference thread + scrub
//! thread with live fault injection), drives it with an open-loop
//! Poisson workload, and reports throughput, latency percentiles, model
//! accuracy under live faults, and the memory-protection counters.
//!
//! Run: `cargo run --release --example serve -- \
//!        --model squeezenet_s --strategy in-place --rps 300 --seconds 10`
//!
//! `--ingress ring|locked` (default ring) selects the front door: the
//! lock-free slab ring or the mutex batcher baseline; `--ring-depth N`
//! sets the ring's slab count.

use std::time::{Duration, Instant};

use zsecc::coordinator::{BatchPolicy, IngressPolicy, Server, ServerConfig};
use zsecc::memory::ScrubPolicy;
use zsecc::model::EvalSet;
use zsecc::util::cli::Args;
use zsecc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let artifacts = zsecc::artifacts_dir();
    let model = args.str_or("model", "squeezenet_s");
    let secs = args.f64_or("seconds", 8.0)?;
    let rps = args.f64_or("rps", 300.0)?;
    let cfg = ServerConfig {
        strategy: args.str_or("strategy", "in-place"),
        policy: BatchPolicy {
            max_batch: args.usize_or("batch", 32)?,
            max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 5)?),
        },
        scrub_interval: Some(Duration::from_millis(args.u64_or("scrub-ms", 250)?)),
        scrub_policy: ScrubPolicy::parse(&args.str_or("scrub-policy", "adaptive"))?,
        scrub_max_interval: None, // 16 x scrub interval
        fault_rate_per_interval: args.f64_or("fault-rate", 1e-6)?,
        fault_seed: args.u64_or("seed", 1)?,
        shards: args.usize_or("shards", 8)?,
        scrub_workers: args.usize_or("scrub-workers", 4)?,
        ingress: IngressPolicy::parse(&args.str_or("ingress", "ring"))?,
        ring_depth: args.usize_or("ring-depth", 8)?,
    };
    println!(
        "serving {model}: strategy={} ingress={} batch<={} max_wait={:?} scrub={:?} ({}) fault={}/interval",
        cfg.strategy,
        cfg.ingress.tag(),
        cfg.policy.max_batch,
        cfg.policy.max_wait,
        cfg.scrub_interval,
        cfg.scrub_policy.tag(),
        cfg.fault_rate_per_interval
    );
    let ds = EvalSet::load(&artifacts.join("dataset.eval.bin"))?;
    let srv = Server::start_pjrt(&artifacts, &model, &cfg)?;

    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut sent = 0u64;
    let (mut answered, mut correct) = (0u64, 0u64);
    while t0.elapsed().as_secs_f64() < secs {
        let idx = rng.below(ds.n as u64) as usize;
        pending.push((srv.submit(ds.image(idx).to_vec())?, ds.labels[idx] as usize));
        sent += 1;
        pending.retain(|(rx, label)| match rx.try_recv() {
            Ok(resp) => {
                answered += 1;
                correct += (resp.pred == *label) as u64;
                false
            }
            Err(_) => true,
        });
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rps)));
    }
    for (rx, label) in pending {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
            answered += 1;
            correct += (resp.pred == label) as u64;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sent={sent} answered={answered} accuracy-under-live-faults={:.4} throughput={:.1} req/s",
        correct as f64 / answered.max(1) as f64,
        answered as f64 / wall
    );
    println!("metrics: {}", srv.metrics.report());
    srv.shutdown();
    Ok(())
}
