//! Fault-injection sweep (a configurable slice of the paper's Table 2).
//!
//! Run: `cargo run --release --example fault_sweep -- \
//!        --models squeezenet_s --trials 3 --rates 1e-4,1e-3 --verbose`

use zsecc::harness::table2;
use zsecc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let artifacts = zsecc::artifacts_dir();
    let mut cfg = table2::Config {
        trials: args.usize_or("trials", 3)?,
        batch: args.usize_or("batch", 256)?,
        ..Default::default()
    };
    let models = args.list_or("models", &["squeezenet_s"]);
    cfg.models = models;
    if let Some(r) = args.str_opt("rates") {
        cfg.rates = r
            .split(',')
            .map(|x| x.parse::<f64>().unwrap())
            .collect();
    }
    let t2 = table2::run(&artifacts, &cfg, args.bool("verbose"))?;
    println!("{}", t2.render(&cfg));
    for (name, ok) in t2.shape_checks(&cfg) {
        println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
    }
    Ok(())
}
