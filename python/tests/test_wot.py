"""WOT (QATT) and ADMM training-scheme behaviour on tiny runs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import admm, data, models, quantize, train, wot


@pytest.fixture(scope="module")
def tiny():
    ds = data.generate(n_train=256, n_eval=128, seed=5)
    m = models.get("inception_s")  # smallest model, fastest
    params, _ = train.pretrain(m, ds, steps=40, bs=32, lr=0.05, momentum=0.9)
    return m, params, ds


def test_throttle_writeback_is_fixed_point(tiny):
    m, params, ds = tiny
    scales = wot.calibration_scales(params, m.protected_names())
    p1, n1 = wot.throttle_params(params, scales)
    p2, n2 = wot.throttle_params(p1, scales)
    assert n2 == 0, "second throttle with frozen scales must be a no-op"


def test_wot_satisfies_constraint_and_logs(tiny):
    m, params, ds = tiny
    p, scales, log = wot.wot_finetune(
        m, params, ds, steps=8, bs=32, lr=1e-4, momentum=0.9,
        weight_decay=1e-4, log_every=2, eval_subset=64,
    )
    q = wot.quantized_weights_flat(p, m.protected_names(), scales)
    assert wot.check_constraint(q) == 0
    assert len(log["step"]) == len(log["n_large"]) == len(log["acc_before"])
    assert log["n_large"][-1] <= log["n_large"][0]
    assert 0.0 <= log["final_acc"] <= 1.0
    # exported buffer is whole blocks of int8
    assert q.dtype == np.int8 and q.size % 8 == 0


def test_wot_lr0_preserves_throttled_accuracy(tiny):
    """With lr=0 the only change is the first throttle; accuracy must be
    flat afterwards (regression test for the rescaling-cascade bug)."""
    m, params, ds = tiny
    p, scales, log = wot.wot_finetune(
        m, params, ds, steps=4, bs=32, lr=0.0, momentum=0.9,
        weight_decay=0.0, log_every=1, eval_subset=64,
    )
    after = log["acc_after"]
    assert max(after) - min(after) < 1e-9
    assert log["n_large"][1:] == [0] * (len(log["n_large"]) - 1)


def test_qat_view_respects_scales(tiny):
    m, params, ds = tiny
    protected = m.protected_names()
    scales = wot.calibration_scales(params, protected)
    qp = wot.qat_view(params, scales)
    for n in protected:
        q = np.asarray(qp[n]) / scales[n]
        np.testing.assert_allclose(q, np.round(q), atol=1e-4)
        assert np.abs(q).max() <= 128.01


def test_admm_runs_and_final_constraint(tiny):
    m, params, ds = tiny
    p, log = admm.admm_wot(
        m, params, ds, outer_iters=2, inner_steps=3, bs=32, eval_subset=64
    )
    assert len(log["n_large"]) == 2
    assert 0.0 <= log["final_acc"] <= 1.0
    # after the final hard clamp the constraint holds
    scales = wot.calibration_scales(p, m.protected_names())
    q = wot.quantized_weights_flat(p, m.protected_names(), scales)
    assert wot.check_constraint(q) == 0
