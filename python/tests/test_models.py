"""Model zoo structural invariants + forward-pass checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, quantize
from compile.models.common import conv2d, dense

KEY = jax.random.PRNGKey(0)
X = jnp.zeros((2, 32, 32, 3), jnp.float32)


@pytest.fixture(scope="module", params=models.ALL_MODELS)
def model_and_params(request):
    m = models.get(request.param)
    return m, m.init(KEY)


def test_registry_complete():
    assert set(models.ALL_MODELS) == set(models.REGISTRY.keys())
    assert set(models.FAULT_MODELS) <= set(models.ALL_MODELS)


def test_forward_shapes(model_and_params):
    m, p = model_and_params
    logits, upd = m.apply(p, X)
    assert logits.shape == (2, 10)
    assert not upd, "eval mode must not emit BN updates"


def test_train_mode_bn_updates(model_and_params):
    m, p = model_and_params
    _, upd = m.apply(p, X, train=True)
    has_bn = any(k.endswith(".mu") for k in p)
    assert bool(upd) == has_bn


def test_protected_tensors_block_aligned(model_and_params):
    m, p = model_and_params
    offset = 0
    for name, shape in m.tensors:
        size = int(np.prod(shape))
        assert size % 8 == 0, f"{m.name}.{name}"
        assert p[name].shape == shape
        offset += size
    assert offset == m.num_weights()


def test_all_protected_weights_affect_output(model_and_params):
    """Every protected tensor must be live in the graph: zeroing it must
    change the logits (catches wiring bugs in _forward)."""
    m, p = model_and_params
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(2, 32, 32, 3)).astype(np.float32))
    base, _ = m.apply(p, x)
    for name, _ in m.tensors:
        p2 = dict(p)
        p2[name] = jnp.zeros_like(p[name])
        alt, _ = m.apply(p2, x)
        assert not np.allclose(np.asarray(base), np.asarray(alt)), (
            f"{m.name}.{name} seems disconnected from the output"
        )


def test_wq_hook_applied(model_and_params):
    """apply(wq=...) must transform protected weights (quantized forward
    differs from float forward for a generic random init)."""
    m, p = model_and_params
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(2, 32, 32, 3)).astype(np.float32))
    a, _ = m.apply(p, x)
    b, _ = m.apply(p, x, wq=lambda w: w * 0.5)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_custom_conv_dense_injection(model_and_params):
    """The conv/dense injection points (used for the Pallas variant) must
    be honoured: an identity-wrapped injection reproduces the default."""
    m, p = model_and_params
    r = np.random.default_rng(3)
    x = jnp.asarray(r.normal(size=(2, 32, 32, 3)).astype(np.float32))
    calls = {"conv": 0, "dense": 0}

    def conv_spy(xx, w, stride=1):
        calls["conv"] += 1
        return conv2d(xx, w, stride)

    def dense_spy(xx, w):
        calls["dense"] += 1
        return dense(xx, w)

    a, _ = m.apply(p, x)
    b, _ = m.apply(p, x, conv=conv_spy, dense_fn=dense_spy)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    n_conv = sum(1 for n, s in m.tensors if len(s) == 4)
    n_dense = sum(1 for n, s in m.tensors if len(s) == 2)
    assert calls["conv"] == n_conv
    assert calls["dense"] == n_dense
