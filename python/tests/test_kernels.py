"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes (including non-block-multiples, which exercise
the padding paths) and value ranges; every kernel must match its oracle
to float32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rng_array(shape, seed, scale=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(0, scale, size=shape).astype(np.float32))


# ------------------------------------------------------------- matmul --


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rng_array((m, k), seed)
    y = rng_array((k, n), seed + 1)
    out = kernels.matmul(x, y, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 64), (64, 64, 64)])
def test_matmul_block_shapes(bm, bn, bk):
    x = rng_array((100, 60), 0)
    y = rng_array((60, 48), 1)
    out = kernels.matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_exact_multiples_no_padding():
    x = rng_array((64, 128), 2)
    y = rng_array((128, 64), 3)
    out = kernels.matmul(x, y, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_vmem_footprint_and_utilization():
    assert kernels.vmem_footprint_bytes(128, 128, 128) == 3 * 128 * 128 * 4
    assert kernels.mxu_utilization(128, 128, 128, 128, 128, 128) == 1.0
    assert kernels.mxu_utilization(65, 128, 128, 64, 128, 128) == pytest.approx(
        65 / 128
    )


# ------------------------------------------------------------- conv2d --


@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([4, 7, 8, 16]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    kk=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, hw, cin, cout, kk, stride, seed):
    x = rng_array((b, hw, hw, cin), seed)
    w = rng_array((kk, kk, cin, cout), seed + 1)
    out = kernels.conv2d_pallas(x, w, stride=stride, bm=32, bn=16, bk=16)
    np.testing.assert_allclose(
        out, ref.conv2d_ref(x, w, stride), rtol=1e-4, atol=1e-4
    )


def test_conv2d_model_shapes():
    # the actual first-layer shape of the zoo
    x = rng_array((2, 32, 32, 3), 0)
    w = rng_array((3, 3, 3, 16), 1)
    out = kernels.conv2d_pallas(x, w)
    assert out.shape == (2, 32, 32, 16)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- fake-quant --


@given(
    n=st.integers(1, 5000),
    scale_pow=st.integers(-8, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_matches_ref(n, scale_pow, seed):
    x = rng_array((n,), seed, scale=3.0)
    s = jnp.float32(2.0**scale_pow)
    out = kernels.fake_quant_pallas(x, s)
    np.testing.assert_allclose(out, ref.fake_quant_ref(x, s), rtol=0, atol=1e-6)


def test_fake_quant_clips_to_int8_range():
    x = jnp.asarray([1000.0, -1000.0, 0.0], jnp.float32)
    s = jnp.float32(1.0)
    out = kernels.fake_quant_pallas(x, s)
    np.testing.assert_allclose(out, [127.0, -128.0, 0.0])


# ------------------------------------------------------------ throttle --


@given(
    nblocks=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_throttle_matches_ref(nblocks, seed):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.integers(-128, 128, size=nblocks * 8).astype(np.float32))
    out = kernels.throttle_pallas(q)
    np.testing.assert_array_equal(out, ref.throttle_ref(q))


def test_throttle_semantics():
    q = jnp.asarray(
        [127.0, -128.0, 63.0, -64.0, 64.0, -65.0, 0.0, 127.0], jnp.float32
    )
    out = np.asarray(kernels.throttle_pallas(q))
    assert list(out) == [63.0, -64.0, 63.0, -64.0, 63.0, -64.0, 0.0, 127.0]
