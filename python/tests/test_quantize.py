"""Quantization (paper Eq. 1) + WOT constraint machinery properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantize

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def arr(seed, n, scale=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(0, scale, size=n).astype(np.float32))


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 2000))
def test_quantize_range_and_roundtrip(seed, n):
    w = arr(seed, n)
    s = quantize.scale_of(w)
    q = np.asarray(quantize.quantize(w, s))
    assert q.min() >= -128 and q.max() <= 127
    # Eq.1: max|X| maps to ±127
    assert np.abs(q).max() == 127 or np.allclose(w, 0)
    # dequantization error bounded by half a step
    dq = np.asarray(quantize.dequantize(jnp.asarray(q), s))
    assert np.abs(dq - np.asarray(w)).max() <= float(s) / 2 + 1e-7


def test_fake_quant_ste_gradient_passthrough():
    w = arr(3, 64)
    g = jax.grad(lambda w: jnp.sum(quantize.fake_quant(w) ** 2))(w)
    # STE: gradient equals that of the dequantized values wrt w = 2*dq
    np.testing.assert_allclose(g, 2 * quantize.fake_quant(w), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), nblocks=st.integers(1, 200))
def test_throttle_constraint_and_idempotence(seed, nblocks):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.integers(-128, 128, size=nblocks * 8).astype(np.float32))
    t = quantize.throttle_q(q)
    blocks = np.asarray(t).reshape(-1, 8)
    assert blocks[:, :7].min() >= -64 and blocks[:, :7].max() <= 63
    # position 7 untouched
    np.testing.assert_array_equal(blocks[:, 7], np.asarray(q).reshape(-1, 8)[:, 7])
    # idempotent
    np.testing.assert_array_equal(np.asarray(quantize.throttle_q(t)), np.asarray(t))
    # large_count after throttle is 0
    assert int(quantize.large_count(t)) == 0


def test_large_count_counts_only_first_seven():
    q = np.zeros(16, np.float32)
    q[7] = 127  # free position
    q[8] = 127  # position 0 of block 1
    assert int(quantize.large_count(jnp.asarray(q))) == 1


@given(seed=st.integers(0, 2**31 - 1))
def test_fixed_scale_throttled_fake_quant_is_stable(seed):
    """The frozen-scale projection must be a fixed point (the dynamic
    rescaling cascade this guards against collapsed WOT; see wot.py)."""
    w = arr(seed, 256, scale=2.0)
    s = float(quantize.scale_of(w))
    w1 = quantize.throttled_fake_quant_fixed(w, s)
    w2 = quantize.throttled_fake_quant_fixed(w1, s)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)


def test_distribution_bands_sum_to_one():
    q = jnp.asarray(np.arange(-128, 128, dtype=np.float32))
    a, b, c = quantize.distribution_bands(q)
    assert float(a + b + c) == 1.0
