"""SynthImageNet generator properties."""

import os
import struct
import tempfile

import numpy as np

from compile import data


def test_deterministic_in_seed():
    a = data.generate(n_train=100, n_eval=50, seed=3)
    b = data.generate(n_train=100, n_eval=50, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_different_seed_differs():
    a = data.generate(n_train=50, n_eval=20, seed=3)
    b = data.generate(n_train=50, n_eval=20, seed=4)
    assert not np.allclose(a[0], b[0])


def test_shapes_and_ranges():
    x_tr, y_tr, x_ev, y_ev = data.generate(n_train=100, n_eval=50, seed=1)
    assert x_tr.shape == (100, 32, 32, 3)
    assert x_ev.shape == (50, 32, 32, 3)
    assert np.abs(x_tr).max() <= 1.0  # tanh-squashed
    assert set(np.unique(y_tr)) <= set(range(10))
    # class balance
    counts = np.bincount(y_ev, minlength=10)
    assert counts.min() == counts.max() == 5


def test_classes_are_separable_by_template():
    """Nearest-class-mean on raw pixels must beat chance by a wide margin
    — guarantees the dataset is learnable."""
    x_tr, y_tr, x_ev, y_ev = data.generate(n_train=500, n_eval=200, seed=7)
    means = np.stack([x_tr[y_tr == c].mean(axis=0).ravel() for c in range(10)])
    correct = 0
    for x, y in zip(x_ev, y_ev):
        d = ((means - x.ravel()) ** 2).sum(axis=1)
        correct += int(np.argmin(d) == y)
    acc = correct / len(y_ev)
    assert acc > 0.5, f"nearest-mean accuracy {acc} too low — dataset unlearnable"


def test_eval_bin_roundtrip():
    x = np.arange(2 * 12, dtype=np.float32).reshape(2, 2, 2, 3) / 10
    y = np.array([3, 7], dtype=np.int32)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "eval.bin")
        data.write_eval_bin(p, x, y)
        raw = open(p, "rb").read()
        n, dim = struct.unpack("<II", raw[:8])
        assert (n, dim) == (2, 12)
        img = np.frombuffer(raw[8 : 8 + n * dim * 4], dtype="<f4")
        np.testing.assert_allclose(img, x.reshape(2, -1).ravel())
        assert list(raw[8 + n * dim * 4 :]) == [3, 7]
