"""Future-work extensions (paper section 6): fewer-bit quantization and
the extended WOT constraint that feeds the zero-space BCH-16 code."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data, models, quantize, train

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(
    bits=st.sampled_from([4, 6, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_generalizes_over_bit_widths(bits, seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(0, 1, size=500).astype(np.float32))
    s = quantize.scale_of(w, bits)
    q = np.asarray(quantize.quantize(w, s, bits))
    qmax = 2 ** (bits - 1) - 1
    assert q.min() >= -qmax - 1 and q.max() <= qmax
    # max |w| maps to the grid edge
    assert np.abs(q).max() == qmax
    # error within half a step
    err = np.abs(np.asarray(w) - q * float(s)).max()
    assert err <= float(s) / 2 + 1e-7


def test_fewer_bits_fewer_noninformative():
    """The paper's section-6 observation: at n bits, a 'small' weight has
    8-n+... fewer spare bits; quantify the fraction of weights with k
    non-informative bits across widths on a trained model."""
    ds = data.generate(n_train=256, n_eval=64, seed=9)
    m = models.get("inception_s")
    params, _ = train.pretrain(m, ds, steps=25, bs=32, lr=0.05, momentum=0.9)
    w = np.concatenate(
        [np.asarray(params[n]).ravel() for n in m.protected_names()]
    )
    wj = jnp.asarray(w)
    frac_small = {}
    for bits in (8, 6, 4):
        s = quantize.scale_of(wj, bits)
        q = np.asarray(quantize.quantize(wj, s, bits))
        # one spare bit = |q| below half the grid
        frac_small[bits] = float((np.abs(q) < 2 ** (bits - 2)).mean())
    # with fewer bits, the same weight distribution concentrates over
    # fewer grid points, so the small-value fraction stays high — the
    # opportunity does not vanish, matching the paper's optimism
    assert frac_small[8] > 0.5
    assert frac_small[4] > 0.3


@given(nblocks=st.integers(1, 120), seed=st.integers(0, 2**31 - 1))
def test_throttle_ext_constraint_and_idempotence(nblocks, seed):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.integers(-128, 128, size=nblocks * 16).astype(np.float32))
    t = quantize.throttle_q_ext(q)
    blocks = np.asarray(t).reshape(-1, 16)
    assert blocks[:, :15].min() >= -32 and blocks[:, :15].max() <= 31
    np.testing.assert_array_equal(
        blocks[:, 15], np.asarray(q).reshape(-1, 16)[:, 15]
    )
    np.testing.assert_array_equal(
        np.asarray(quantize.throttle_q_ext(t)), np.asarray(t)
    )
    assert int(quantize.large_count_ext(t)) == 0


def test_ext_constraint_is_strictly_stronger():
    """Every ext-constrained buffer also satisfies the standard WOT
    constraint (so BCH-16 weights remain in-place-SEC-DED encodable)."""
    r = np.random.default_rng(3)
    q = jnp.asarray(r.integers(-128, 128, size=64 * 16).astype(np.float32))
    t = quantize.throttle_q_ext(q)
    # positions 0..6 of each 8-block are within [-64,63]: ext clamps to
    # [-32,31] except bytes 15, 31, ... — byte 7 and 15 of a 16-block:
    # byte 7 is ext-clamped (<=31), byte 15 is free in both schemes.
    assert int(quantize.large_count(t)) == 0
