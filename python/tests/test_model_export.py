"""L2 export graph: flat-buffer wiring, fast-vs-pallas equivalence, and
HLO text generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model as model_mod, models, quantize, wot


@pytest.fixture(scope="module")
def small():
    m = models.get("inception_s")
    params = m.init(jax.random.PRNGKey(1))
    return m, params


def flat_from_params(m, params, scales):
    q = wot.quantized_weights_flat(params, m.protected_names(), scales)
    table = model_mod.layer_table(m)
    return model_mod.dequant_flat(q, table, scales), q


def test_layer_table_tiles_buffer(small):
    m, params = small
    table = model_mod.layer_table(m)
    at = 0
    for rec in table:
        assert rec["offset"] == at
        at += rec["size"]
    assert at == m.num_weights()


def test_split_flat_reshapes(small):
    m, params = small
    table = model_mod.layer_table(m)
    wflat = jnp.arange(m.num_weights(), dtype=jnp.float32)
    parts = model_mod.split_flat(wflat, table)
    assert set(parts) == set(m.protected_names())
    for rec in table:
        assert parts[rec["name"]].shape == tuple(rec["shape"])
        np.testing.assert_allclose(
            np.asarray(parts[rec["name"]]).ravel()[0], rec["offset"]
        )


def test_infer_from_flat_matches_direct_apply(small):
    """Feeding the dequantized flat buffer through the export graph must
    equal applying the throttled fake-quant params directly."""
    m, params = small
    scales = wot.calibration_scales(params, m.protected_names())
    params, _ = wot.throttle_params(params, scales)
    wflat, _ = flat_from_params(m, params, scales)
    r = np.random.default_rng(0)
    x = r.normal(size=(4, 32, 32, 3)).astype(np.float32)
    infer = model_mod.make_infer(m, params, batch=4)
    (logits,) = infer(wflat, jnp.asarray(x.reshape(4, -1)))
    qp = wot.qat_view(params, scales, throttled=True)
    direct, _ = m.apply(qp, jnp.asarray(x))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(direct), rtol=1e-4, atol=1e-4
    )


def test_pallas_variant_matches_fast(small):
    m, params = small
    scales = wot.calibration_scales(params, m.protected_names())
    params, _ = wot.throttle_params(params, scales)
    wflat, _ = flat_from_params(m, params, scales)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(2, 3072)).astype(np.float32))
    fast = model_mod.make_infer(m, params, batch=2, use_pallas=False)
    pallas = model_mod.make_infer(m, params, batch=2, use_pallas=True)
    (a,) = fast(wflat, x)
    (b,) = pallas(wflat, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_hlo_text_export(small):
    m, params = small
    text = model_mod.lower_to_hlo_text(m, params, batch=1)
    assert "HloModule" in text
    assert "f32[1,3072]" in text  # the images parameter
    assert f"f32[{m.num_weights()}]" in text  # the weights parameter


def test_hlo_pallas_export_contains_loops(small):
    m, params = small
    text = model_mod.lower_to_hlo_text(m, params, batch=1, use_pallas=True)
    assert "HloModule" in text
    # interpret-mode pallas lowers its grid to XLA control flow
    assert "while" in text or "dynamic-update-slice" in text
