#!/usr/bin/env python3
"""Bench-regression guard over the BENCH_ecc.json JSON-lines ledger.

The ledger is append-only: every CI run (and any local
``cargo bench --bench ecc_hotpath -- --out BENCH_ecc.json``) adds one
record. This guard compares the freshly appended record (the last line)
against the previous *measured* record — the latest earlier line that
carries ``tile`` and ``pool`` sections; schema-note lines don't count —
and fails on a >25% throughput drop in either section:

* ``tile``: per-strategy clean-decode GB/s (``<strategy>/scalar`` and
  ``<strategy>/tiled`` keys), compared key by key;
* ``pool``: the ``scoped_gbps``/``pool_gbps`` arrays, compared element
  by element (positions index the shard-count sweep);
* ``serving.ingress``: the ``ring_mreqs``/``locked_mreqs`` arrays
  (million req/s over the producer-count sweep), compared element by
  element — only when both records carry the section, so ledgers
  predating it stay comparable.

Exit codes: 0 pass/skip, 1 regression. Set ``BENCH_WARN_ONLY=1`` to
demote regressions to warnings (exit 0) while a legitimate perf change
lands; the comparison is still printed.

``--self-test`` runs the comparison logic against fabricated records
and exits nonzero on any logic error — CI runs it first, so the guard
itself is exercised even while the ledger holds no measured history.
"""

import json
import os
import sys

THRESHOLD = 0.25  # fail when new < old * (1 - THRESHOLD)


def is_measured(record):
    """A record produced by the bench (not a schema note)."""
    return isinstance(record, dict) and "tile" in record and "pool" in record


def comparable(old, new):
    """Records measured at different bench sizes (e.g. a committed local
    1 MiB run vs CI's 64 KiB) are not comparable — GB/s shifts from the
    working-set size alone would swamp the 25% gate."""
    return old.get("bytes_per_op") == new.get("bytes_per_op")


def load_ledger(path):
    records = []
    with open(path, encoding="utf-8") as fh:
        for line_no, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as err:
                raise SystemExit(f"{path}:{line_no}: unparseable ledger line: {err}")
    return records


def section_pairs(old, new):
    """Yield (label, old, new, unit) for every guarded metric."""
    old_tile, new_tile = old.get("tile", {}), new.get("tile", {})
    for key in sorted(old_tile):
        if key in new_tile:
            yield f"tile/{key}", old_tile[key], new_tile[key], "GB/s"
    old_pool, new_pool = old.get("pool", {}), new.get("pool", {})
    for series in ("scoped_gbps", "pool_gbps"):
        olds, news = old_pool.get(series, []), new_pool.get(series, [])
        shards = old_pool.get("shards", [])
        for i, (o, n) in enumerate(zip(olds, news)):
            label = f"{shards[i]:g}sh" if i < len(shards) else str(i)
            yield f"pool/{series}[{label}]", o, n, "GB/s"
    old_ing = old.get("serving", {}).get("ingress", {})
    new_ing = new.get("serving", {}).get("ingress", {})
    producers = old_ing.get("producers", [])
    for series in ("ring_mreqs", "locked_mreqs"):
        olds, news = old_ing.get(series, []), new_ing.get(series, [])
        for i, (o, n) in enumerate(zip(olds, news)):
            label = f"{producers[i]:g}p" if i < len(producers) else str(i)
            yield f"serving/ingress/{series}[{label}]", o, n, "Mreq/s"


def compare(old, new, threshold=THRESHOLD):
    """Return the list of regressions as (label, old, new, drop)."""
    regressions = []
    for label, o, n, unit in section_pairs(old, new):
        if not (isinstance(o, (int, float)) and isinstance(n, (int, float))):
            continue
        if o <= 0:
            continue
        drop = 1.0 - n / o
        marker = "REGRESSION" if drop > threshold else "ok"
        print(f"  {label:<34} {o:10.3f} -> {n:10.3f} {unit:<6} ({-drop:+7.1%}) {marker}")
        if drop > threshold:
            regressions.append((label, o, n, drop))
    return regressions


def self_test():
    old = {
        "tile": {"ecc/scalar": 10.0, "ecc/tiled": 40.0, "zero/tiled": 8.0},
        "pool": {"shards": [4, 16], "scoped_gbps": [5.0, 6.0], "pool_gbps": [7.0, 8.0]},
    }
    flat = {
        "tile": {"ecc/scalar": 9.0, "ecc/tiled": 39.0, "zero/tiled": 8.4},
        "pool": {"shards": [4, 16], "scoped_gbps": [4.9, 5.0], "pool_gbps": [6.9, 7.9]},
    }
    slow = {
        "tile": {"ecc/scalar": 10.0, "ecc/tiled": 20.0, "zero/tiled": 8.0},
        "pool": {"shards": [4, 16], "scoped_gbps": [5.0, 6.0], "pool_gbps": [7.0, 3.0]},
    }
    print("[self-test] within-threshold record:")
    assert compare(old, flat) == [], "noise within 25% must pass"
    print("[self-test] regressed record:")
    bad = compare(old, slow)
    assert [b[0] for b in bad] == ["tile/ecc/tiled", "pool/pool_gbps[16sh]"], bad
    note = {"bench": "ecc_hotpath", "note": "schema"}
    assert not is_measured(note) and is_measured(old)
    # mismatched shard sweeps only compare the common prefix
    short = {"tile": {}, "pool": {"shards": [4], "pool_gbps": [7.0]}}
    assert compare(old, short) == []
    # serving.ingress: guarded elementwise when both records carry it,
    # silently skipped when either side predates the section
    ing = {
        "serving": {
            "ingress": {
                "producers": [1, 4],
                "ring_mreqs": [2.0, 5.0],
                "locked_mreqs": [2.0, 1.5],
            }
        }
    }
    ing_slow = {
        "serving": {
            "ingress": {
                "producers": [1, 4],
                "ring_mreqs": [1.9, 2.0],
                "locked_mreqs": [1.9, 1.4],
            }
        }
    }
    print("[self-test] serving.ingress regressed record:")
    bad = compare({**old, **ing}, {**flat, **ing_slow})
    assert [b[0] for b in bad] == ["serving/ingress/ring_mreqs[4p]"], bad
    assert compare({**old, **ing}, flat) == [], "absent section must be skipped"
    assert compare(old, {**flat, **ing_slow}) == [], "absent old section too"
    # records from different bench sizes must not be compared at all
    ci = {**old, "bytes_per_op": 65536}
    local = {**old, "bytes_per_op": 1 << 20}
    assert comparable(ci, dict(ci)) and not comparable(local, ci)
    print("[self-test] all comparisons behave; guard logic OK")


def main(argv):
    if len(argv) == 2 and argv[1] == "--self-test":
        self_test()
        return 0
    if len(argv) != 2:
        print(__doc__)
        return 2
    records = load_ledger(argv[1])
    if not records:
        raise SystemExit(f"{argv[1]}: empty ledger")
    new = records[-1]
    if not is_measured(new):
        raise SystemExit(f"{argv[1]}: last line is not a measured bench record")
    priors = [r for r in records[:-1] if is_measured(r)]
    if not priors:
        print("bench guard: no prior measured record in the ledger — skipping")
        return 0
    old = priors[-1]
    if not comparable(old, new):
        print(
            f"bench guard: previous measured record is a different bench size "
            f"({old.get('bytes_per_op')} vs {new.get('bytes_per_op')} bytes/op) — skipping"
        )
        return 0
    print(
        f"bench guard: comparing against previous measured record "
        f"({old.get('bytes_per_op', '?')} bytes/op), threshold {THRESHOLD:.0%}"
    )
    regressions = compare(old, new)
    if not regressions:
        print("bench guard: OK")
        return 0
    for label, o, n, drop in regressions:
        print(f"bench guard: {label} dropped {drop:.1%} ({o:.3f} -> {n:.3f} GB/s)")
    if os.environ.get("BENCH_WARN_ONLY") == "1":
        print("bench guard: BENCH_WARN_ONLY=1 — reporting only, not failing")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
