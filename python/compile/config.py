"""Build-time training configuration for the model zoo.

The paper fine-tunes ImageNet-pretrained models with SGD (lr 1e-4,
momentum 0.9, lambda 1e-4). We train small counterparts from scratch on
SynthImageNet, so the pretraining lr is larger; WOT fine-tuning then uses
a small lr exactly like the paper. Steps are sized so `make artifacts`
completes in a few CPU minutes; QUICK overrides (used by pytest) shrink
everything.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TrainCfg:
    pretrain_steps: int = 700
    pretrain_lr: float = 0.05
    wot_steps: int = 300
    wot_lr: float = 3e-4
    batch_size: int = 64
    momentum: float = 0.9
    weight_decay: float = 1e-4  # the paper's lambda (Frobenius regularizer)
    log_every: int = 25


# Per-model overrides. BN-free nets need smaller learning rates (lr 0.03+
# diverges them on SynthImageNet); squeezenet's 1x1-heavy stack is the most
# sensitive.
CFGS = {
    "alexnet_s": TrainCfg(pretrain_lr=0.01),
    "vgg16_s": TrainCfg(pretrain_steps=900, pretrain_lr=0.01),
    "vgg16bn_s": TrainCfg(pretrain_steps=900, pretrain_lr=0.05),
    "inception_s": TrainCfg(),
    "resnet18_s": TrainCfg(pretrain_steps=900),
    "squeezenet_s": TrainCfg(pretrain_steps=900, pretrain_lr=0.003),
}

QUICK = TrainCfg(pretrain_steps=30, wot_steps=15, log_every=5)

# Batch sizes of the exported inference executables.
EXPORT_BATCHES = (1, 32, 256)
PALLAS_BATCH = 32  # batch of the pallas-kernel artifact variant
DATA_SEED = 7
INIT_SEED = 3


def cfg_for(name: str, quick: bool = False) -> TrainCfg:
    return QUICK if quick else CFGS[name]
