"""Symmetric range-based linear quantization (paper Eq. 1) + WOT utilities.

    X_q = round(X * (2^(n-1) - 1) / max|X|),   n = 8

plus the WOT block constraint: when int8 weights are laid out in memory,
every 8-byte (64-bit) block may have a value outside [-64, 63] **only in
its last byte** — the first seven bytes each then carry a non-informative
bit (bit6 == bit7) that in-place ECC reuses for check-bit storage.

All functions are pure jnp so they can be jitted into both the training
step and the exported inference graph; Pallas-kernel versions of
fake-quant and throttle live in kernels/ and are checked against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127  # 2^(8-1) - 1
QMIN = -128
SMALL_LO = -64  # WOT small-weight range [-64, 63]
SMALL_HI = 63
BLOCK = 8  # bytes per protected 64-bit block
FREE_POS = BLOCK - 1  # the one position allowed to hold a large weight


def scale_of(w: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Dequantization scale max|X| / (2^(bits-1) - 1) (Eq. 1 inverted).

    `bits` generalizes to the paper's future-work direction (section 6):
    fewer-bit quantizations have fewer non-informative bits, so the
    trade between code strength and quantization error can be studied.
    Never zero.
    """
    m = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    return m / (2 ** (bits - 1) - 1)


def quantize(w: jnp.ndarray, scale: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Float -> int grid (returned as float carrying integer values)."""
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q * scale


def fake_quant(w: jnp.ndarray) -> jnp.ndarray:
    """Quantize->dequantize with straight-through estimator gradient."""
    s = scale_of(w)
    dq = dequantize(quantize(w, s), s)
    return w + jax.lax.stop_gradient(dq - w)


def fake_quant_fixed(w: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Fake-quant with a *frozen* calibration scale, STE gradient.

    WOT must use frozen per-layer scales: throttling clamps the large
    weights, which can shrink max|W|; a dynamically recomputed scale then
    re-exposes previously-small weights as 'large', and repeated
    throttle/rescale rounds cascade into an accuracy collapse. Freezing
    the scale at its pre-WOT calibration value (standard static-range
    quantization) makes the throttle projection idempotent.
    """
    dq = dequantize(quantize(w, scale), scale)
    return w + jax.lax.stop_gradient(dq - w)


def throttled_fake_quant_fixed(w: jnp.ndarray, scale: float) -> jnp.ndarray:
    """Throttled fake-quant with a frozen scale, STE gradient."""
    q = throttle_q(quantize(w, scale).reshape(-1)).reshape(w.shape)
    dq = dequantize(q, scale)
    return w + jax.lax.stop_gradient(dq - w)


def fake_quant_act(x: jnp.ndarray) -> jnp.ndarray:
    """Activation fake-quant (dynamic per-tensor range), STE."""
    s = scale_of(x)
    dq = dequantize(quantize(x, s), s)
    return x + jax.lax.stop_gradient(dq - x)


def throttle_q(q: jnp.ndarray) -> jnp.ndarray:
    """WOT throttling on a flat int8-grid vector (length % 8 == 0).

    Clamp positions 0..6 of every 8-value block to [-64, 63]; position 7
    is free. (Paper section 4.1, step 2 of QATT.)
    """
    blocks = q.reshape(-1, BLOCK)
    pos = jnp.arange(BLOCK)
    clamped = jnp.clip(blocks, SMALL_LO, SMALL_HI)
    out = jnp.where(pos[None, :] < FREE_POS, clamped, blocks)
    return out.reshape(q.shape)


def large_count(q: jnp.ndarray) -> jnp.ndarray:
    """Number of values outside [-64, 63] in positions 0..6 (Fig. 3 metric)."""
    blocks = q.reshape(-1, BLOCK)
    pos = jnp.arange(BLOCK)
    large = (blocks < SMALL_LO) | (blocks > SMALL_HI)
    return jnp.sum(large & (pos[None, :] < FREE_POS))


def throttled_fake_quant(w: jnp.ndarray) -> jnp.ndarray:
    """Fake-quant whose quantized value respects the WOT constraint, STE.

    Used in the QAT forward pass so the loss 'sees' the throttled weights.
    """
    s = scale_of(w)
    q = throttle_q(quantize(w, s))
    dq = dequantize(q, s)
    return w + jax.lax.stop_gradient(dq - w)


def pad_to_block(n: int) -> int:
    """Smallest multiple of BLOCK >= n."""
    return (n + BLOCK - 1) // BLOCK * BLOCK


# ---- extended constraint (BCH-16 zero-space DEC; paper section 6) ----

EXT_BLOCK = 16  # bytes per 128-bit block
EXT_LO = -32  # two non-informative bits per small weight
EXT_HI = 31
EXT_FREE_POS = EXT_BLOCK - 1


def throttle_q_ext(q: jnp.ndarray) -> jnp.ndarray:
    """Extended WOT throttling: positions 0..14 of every 16-value block
    clamped to [-32, 31] (two free bits each -> 30 free bits per block,
    enough for a 16-check-bit double-error-correcting BCH code)."""
    blocks = q.reshape(-1, EXT_BLOCK)
    pos = jnp.arange(EXT_BLOCK)
    clamped = jnp.clip(blocks, EXT_LO, EXT_HI)
    return jnp.where(pos[None, :] < EXT_FREE_POS, clamped, blocks).reshape(q.shape)


def large_count_ext(q: jnp.ndarray) -> jnp.ndarray:
    """Extended-constraint violations (Fig-3 analogue for BCH-16)."""
    blocks = q.reshape(-1, EXT_BLOCK)
    pos = jnp.arange(EXT_BLOCK)
    large = (blocks < EXT_LO) | (blocks > EXT_HI)
    return jnp.sum(large & (pos[None, :] < EXT_FREE_POS))


def distribution_bands(q: jnp.ndarray):
    """Fractions of |q| in [0,32), [32,64), [64,128] (Table 1 rows)."""
    a = jnp.abs(q)
    n = q.size
    return (
        jnp.sum(a < 32) / n,
        jnp.sum((a >= 32) & (a < 64)) / n,
        jnp.sum(a >= 64) / n,
    )
