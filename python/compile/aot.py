"""Build-time orchestrator: train (cached) -> WOT -> AOT export.

Run as `python -m compile.aot --out ../artifacts` (the Makefile's
`artifacts` target). Python never runs after this: the rust binary
consumes only the files written here.

Per model (6 zoo models):
  <m>.manifest.json      layer table (name/shape/offset/size/scale),
                         accuracies, file index
  <m>.weights.bin        post-WOT int8 weight buffer (canonical layout)
  <m>.prewot.bin         pre-WOT int8 buffer (Fig-1 / Table-1 input)
  <m>.b{1,32,256}.hlo.txt        "fast" inference graphs
  <m>.b32.pallas.hlo.txt         L1-Pallas-kernel variant (same math)
  <m>.prewot.b256.hlo.txt        pre-WOT graph (Table-1 int8 accuracy)
  <m>.wot_log.json       Fig-3 / Fig-4 series
plus dataset.eval.bin (shared eval split) and squeezenet_s.admm_log.json
(the ADMM baseline ablation).

Everything is cached under <out>/ckpt: re-running is a no-op unless
sources changed (the Makefile stamps that) or --force is given.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import admm as admm_mod
from . import config, data
from . import model as model_mod
from . import models, quantize, train, wot


def _save_params(path: str, params) -> None:
    np.savez_compressed(path, **{k: np.asarray(v) for k, v in params.items()})


def _load_params(path: str):
    z = np.load(path)
    return {k: jnp.asarray(z[k]) for k in z.files}


def _export_model(out: str, name: str, dataset, quick: bool, force: bool) -> dict:
    cfg = config.cfg_for(name, quick)
    ckpt_dir = os.path.join(out, "ckpt")
    os.makedirs(ckpt_dir, exist_ok=True)
    mdl = models.get(name)
    x_tr, y_tr, x_ev, y_ev = dataset

    # ---- stage 1: float32 pretraining (cached) ----------------------
    pre_path = os.path.join(ckpt_dir, f"{name}.pre.npz")
    meta_path = os.path.join(ckpt_dir, f"{name}.meta.json")
    meta = {}
    if os.path.exists(meta_path) and not force:
        meta = json.load(open(meta_path))
    if os.path.exists(pre_path) and not force:
        pre_params = _load_params(pre_path)
    else:
        t0 = time.time()
        pre_params, float_acc = train.pretrain(
            mdl,
            dataset,
            cfg.pretrain_steps,
            cfg.batch_size,
            cfg.pretrain_lr,
            cfg.momentum,
            seed=config.INIT_SEED,
        )
        meta["float_acc"] = float_acc
        meta["pretrain_secs"] = time.time() - t0
        _save_params(pre_path, pre_params)
        json.dump(meta, open(meta_path, "w"))
    if "int8_acc" not in meta:
        meta["int8_acc"] = train.int8_accuracy(mdl, pre_params, x_ev, y_ev)
        json.dump(meta, open(meta_path, "w"))
    print(
        f"[{name}] float_acc={meta['float_acc']:.4f} int8_acc={meta['int8_acc']:.4f}",
        flush=True,
    )

    # ---- stage 2: WOT (cached) ---------------------------------------
    wot_path = os.path.join(ckpt_dir, f"{name}.wot.npz")
    log_path = os.path.join(out, f"{name}.wot_log.json")
    if os.path.exists(wot_path) and os.path.exists(log_path) and not force:
        wot_params = _load_params(wot_path)
        wlog = json.load(open(log_path))
        scales = wlog["scales"]
    else:
        t0 = time.time()
        wot_params, scales, wlog = wot.wot_finetune(
            mdl,
            pre_params,
            dataset,
            cfg.wot_steps,
            cfg.batch_size,
            cfg.wot_lr,
            cfg.momentum,
            cfg.weight_decay,
            log_every=cfg.log_every,
        )
        wlog["model"] = name
        wlog["int8_acc"] = meta["int8_acc"]
        wlog["scales"] = scales
        wlog["wot_secs"] = time.time() - t0
        _save_params(wot_path, wot_params)
        json.dump(wlog, open(log_path, "w"))
    print(f"[{name}] wot final_acc={wlog['final_acc']:.4f}", flush=True)

    # ---- stage 3: binary weight buffers ------------------------------
    protected = mdl.protected_names()
    qflat = wot.quantized_weights_flat(wot_params, protected, scales)
    assert wot.check_constraint(qflat) == 0, "WOT constraint violated at export"
    qflat.tofile(os.path.join(out, f"{name}.weights.bin"))
    # pre-WOT buffer: plain quantization, NO throttle clamp (Fig 1 needs
    # the natural large-value distribution).
    chunks = []
    pre_scales = {}
    for n in protected:
        w = pre_params[n]
        s = float(quantize.scale_of(w))
        pre_scales[n] = s
        chunks.append(np.asarray(quantize.quantize(w, s)).astype(np.int8).reshape(-1))
    np.concatenate(chunks).tofile(os.path.join(out, f"{name}.prewot.bin"))

    # ---- stage 4: HLO export -----------------------------------------
    # NB: `scales` are the frozen WOT calibration scales — the manifest
    # records exactly the grid the int8 buffer was quantized on.
    table = model_mod.layer_table(mdl)
    files = {
        "weights": f"{name}.weights.bin",
        "prewot": f"{name}.prewot.bin",
        "wot_log": f"{name}.wot_log.json",
        "hlo": {},
        "hlo_pallas": {},
        "hlo_prewot": {},
    }
    def write_hlo(fn: str, text: str) -> None:
        # Guard against the constant-elision foot-gun (see model.py):
        # an elided constant would silently decode as zeros in rust.
        assert "constant({...})" not in text, f"{fn}: elided constants in HLO text"
        with open(os.path.join(out, fn), "w") as f:
            f.write(text)

    for b in config.EXPORT_BATCHES:
        fn = f"{name}.b{b}.hlo.txt"
        write_hlo(fn, model_mod.lower_to_hlo_text(mdl, wot_params, b, use_pallas=False))
        files["hlo"][str(b)] = fn
    fn = f"{name}.b{config.PALLAS_BATCH}.pallas.hlo.txt"
    write_hlo(
        fn,
        model_mod.lower_to_hlo_text(
            mdl, wot_params, config.PALLAS_BATCH, use_pallas=True
        ),
    )
    files["hlo_pallas"][str(config.PALLAS_BATCH)] = fn
    b = max(config.EXPORT_BATCHES)
    fn = f"{name}.prewot.b{b}.hlo.txt"
    write_hlo(fn, model_mod.lower_to_hlo_text(mdl, pre_params, b, use_pallas=False))
    files["hlo_prewot"][str(b)] = fn

    # ---- stage 5: manifest -------------------------------------------
    for rec in table:
        rec["scale"] = scales[rec["name"]]
        rec["scale_prewot"] = pre_scales[rec["name"]]
    manifest = {
        "model": name,
        "num_classes": mdl.num_classes,
        "img_size": data.IMG_SIZE,
        "input_dim": data.IMG_DIM,
        "num_weights": mdl.num_weights(),
        "float_acc": meta["float_acc"],
        "int8_acc": meta["int8_acc"],
        "wot_acc": wlog["final_acc"],
        "batches": list(config.EXPORT_BATCHES),
        "pallas_batch": config.PALLAS_BATCH,
        "layers": table,
        "files": files,
    }
    with open(os.path.join(out, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(models.ALL_MODELS))
    ap.add_argument("--quick", action="store_true", help="tiny steps (tests)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-admm", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    n_train, n_eval = (1024, 256) if args.quick else (8000, 1024)
    dataset = data.cached(
        os.path.join(out, "cache"),
        n_train=n_train,
        n_eval=n_eval,
        seed=config.DATA_SEED,
    )
    data.write_eval_bin(os.path.join(out, "dataset.eval.bin"), dataset[2], dataset[3])

    names = [m for m in args.models.split(",") if m]
    index = {}
    for name in names:
        index[name] = f"{name}.manifest.json"
        _export_model(out, name, dataset, args.quick, args.force)

    # ADMM baseline ablation log (paper: ADMM fails to clear positions
    # 0..6; the ablation bench contrasts it with QATT).
    admm_path = os.path.join(out, "squeezenet_s.admm_log.json")
    if not args.skip_admm and "squeezenet_s" in names and not os.path.exists(admm_path):
        mdl = models.get("squeezenet_s")
        pre = _load_params(os.path.join(out, "ckpt", "squeezenet_s.pre.npz"))
        outer, inner = (2, 5) if args.quick else (6, 40)
        _, alog = admm_mod.admm_wot(
            mdl, pre, dataset, outer_iters=outer, inner_steps=inner
        )
        alog["model"] = "squeezenet_s"
        with open(admm_path, "w") as f:
            json.dump(alog, f)

    with open(os.path.join(out, "index.json"), "w") as f:
        json.dump({"models": index, "eval": "dataset.eval.bin"}, f, indent=1)
    print(f"artifacts written to {out}")


if __name__ == "__main__":
    main()
