"""ADMM-based WOT baseline (paper section 4.1, Eqs. 4-9).

The paper formulates the block constraint in the ADMM framework (after
[Zhang et al. ECCV'18]) and reports that it fails to drive the large
values out of positions 0..6, forcing a lossy hard clamp at the end.
We implement it as the comparison baseline for the ablation bench: the
harness contrasts its constraint-violation trajectory and post-clamp
accuracy against QATT's.

  W-step (Eq. 7): SGD on f(W_q) + lambda ||W_q||^2 + gamma ||W_q - Z + U||^2
  Z-step (Eq. 8): Z = project_S(W_q + U)   (the throttle projection)
  U-step (Eq. 9): U = U + W_q - Z

Scales are frozen at calibration like QATT (quantize.fake_quant_fixed).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize, train, wot
from .models.common import ModelDef, Params


def admm_wot(
    model: ModelDef,
    params: Params,
    data,
    outer_iters: int = 6,
    inner_steps: int = 40,
    bs: int = 64,
    lr: float = 1e-3,
    momentum: float = 0.9,
    lam: float = 1e-4,
    gamma: float = 1e-3,
    seed: int = 13,
    eval_subset: int = 512,
):
    """Returns (params, log). The log mirrors wot.wot_finetune's schema so
    the rust ablation harness can plot both."""
    x_tr, y_tr, x_ev, y_ev = data
    protected = model.protected_names()
    scales = wot.calibration_scales(params, protected)
    xs, ys = x_ev[:eval_subset], y_ev[:eval_subset]

    # Z, U live on the (dequantized) weight scale, per protected tensor.
    Z = {n: quantize.fake_quant_fixed(params[n], scales[n]) for n in protected}
    U = {n: jnp.zeros_like(params[n]) for n in protected}

    def loss_fn(p: Params, x, y, Z: Dict, U: Dict):
        qp = wot.qat_view(p, scales)
        logits, upd = model.apply(qp, x, train=True)
        loss = train.cross_entropy(logits, y)
        for n in protected:
            loss = loss + lam * jnp.sum(jnp.square(qp[n]))
            loss = loss + gamma * jnp.sum(jnp.square(qp[n] - Z[n] + U[n]))
        return loss, upd

    @jax.jit
    def step(p: Params, mom: Params, x, y, Z: Dict, U: Dict):
        (_, upd), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y, Z, U)
        new_mom = jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
        new_p = jax.tree.map(lambda a, m: a - lr * m, p, new_mom)
        new_p.update(upd)
        return new_p, new_mom

    log: Dict[str, List[float]] = {
        "step": [],
        "n_large": [],
        "acc_before": [],
        "acc_after": [],
    }
    mom = train.zeros_like_params(params)
    gstep = 0
    for _ in range(outer_iters):
        # W-step: a few SGD passes on the augmented Lagrangian.
        for xb, yb in train.batches(x_tr, y_tr, bs, inner_steps, seed + gstep):
            params, mom = step(params, mom, jnp.asarray(xb), jnp.asarray(yb), Z, U)
            gstep += 1
        # Z-step (projection) + U-step, per tensor.
        for n in protected:
            s = scales[n]
            wq = quantize.fake_quant_fixed(params[n], s)
            v = jnp.round((wq + U[n]) / s)  # int8-grid coordinates
            zq = quantize.throttle_q(v.reshape(-1)).reshape(v.shape)
            Z[n] = zq * s
            U[n] = U[n] + wq - Z[n]
        # Log: constraint violations of W itself (the paper's observation
        # is that this does NOT go to zero under ADMM) + accuracies
        # without/with the hard clamp.
        viol = sum(
            int(
                quantize.large_count(
                    quantize.quantize(params[n], scales[n]).reshape(-1)
                )
            )
            for n in protected
        )
        log["step"].append(gstep)
        log["n_large"].append(viol)
        log["acc_before"].append(wot.eval_acc(model, params, scales, xs, ys, False))
        log["acc_after"].append(wot.eval_acc(model, params, scales, xs, ys, True))

    # The paper's endgame for ADMM: bound the remaining large values (the
    # lossy hard clamp that QATT avoids needing).
    params, _ = wot.throttle_params(params, scales)
    log["final_acc"] = wot.eval_acc(model, params, scales, x_ev, y_ev, True)
    return params, log
