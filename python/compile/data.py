"""SynthImageNet: a procedurally generated, class-structured image dataset.

The paper evaluates on ImageNet (ILSVRC2012), which is not available in
this environment. The protection technique only requires (a) CNNs whose
trained weights concentrate near zero and (b) a measurable accuracy under
weight corruption; both hold for any non-trivially learnable dataset
(DESIGN.md section 2). SynthImageNet provides that: each class is a bank
of oriented sinusoid + blob templates, and each sample is an affine-jittered,
noise-corrupted draw from its class bank. The generator is fully
deterministic given a seed, so python training and the rust-side eval see
byte-identical data.

Images are 32x32x3 float32 in [-1, 1]; NUM_CLASSES = 10.
"""

from __future__ import annotations

import os
import struct
from dataclasses import dataclass

import numpy as np

IMG_SIZE = 32
NUM_CLASSES = 10
IMG_DIM = IMG_SIZE * IMG_SIZE * 3


@dataclass
class ClassTemplate:
    """Parameters of one class's generative template."""

    freqs: np.ndarray  # (K, 2) spatial frequency per sinusoid
    phases: np.ndarray  # (K,)
    chan_mix: np.ndarray  # (K, 3) per-channel amplitude of each sinusoid
    blobs: np.ndarray  # (B, 5): cx, cy, sigma, amp, channel-weighting seed
    blob_chan: np.ndarray  # (B, 3)


def _make_templates(rng: np.random.Generator, k: int = 4, b: int = 3):
    templates = []
    for _ in range(NUM_CLASSES):
        # Distinct dominant orientation/frequency band per class keeps the
        # task solvable by small convnets while noise keeps it non-trivial.
        theta = rng.uniform(0, np.pi, size=k)
        radius = rng.uniform(1.5, 5.0, size=k)
        freqs = np.stack([radius * np.cos(theta), radius * np.sin(theta)], axis=1)
        phases = rng.uniform(0, 2 * np.pi, size=k)
        chan_mix = rng.normal(0, 1, size=(k, 3))
        blobs = np.stack(
            [
                rng.uniform(0.2, 0.8, size=b),  # cx
                rng.uniform(0.2, 0.8, size=b),  # cy
                rng.uniform(0.08, 0.2, size=b),  # sigma
                rng.uniform(0.5, 1.5, size=b),  # amp
                rng.uniform(0, 1, size=b),  # unused seed slot
            ],
            axis=1,
        )
        blob_chan = rng.normal(0, 1, size=(b, 3))
        templates.append(ClassTemplate(freqs, phases, chan_mix, blobs, blob_chan))
    return templates


def _render(
    tpl: ClassTemplate, rng: np.random.Generator, n: int, noise: float
) -> np.ndarray:
    """Render n samples of one class: affine-jittered template + noise."""
    ys, xs = np.mgrid[0:IMG_SIZE, 0:IMG_SIZE].astype(np.float32) / IMG_SIZE
    out = np.zeros((n, IMG_SIZE, IMG_SIZE, 3), dtype=np.float32)
    for i in range(n):
        ang = rng.uniform(-0.3, 0.3)
        scale = rng.uniform(0.85, 1.15)
        dx, dy = rng.uniform(-0.12, 0.12, size=2)
        ca, sa = np.cos(ang), np.sin(ang)
        u = ((xs - 0.5 + dx) * ca - (ys - 0.5 + dy) * sa) * scale
        v = ((xs - 0.5 + dx) * sa + (ys - 0.5 + dy) * ca) * scale
        img = np.zeros((IMG_SIZE, IMG_SIZE, 3), dtype=np.float32)
        for j in range(tpl.freqs.shape[0]):
            wave = np.sin(
                2 * np.pi * (tpl.freqs[j, 0] * u + tpl.freqs[j, 1] * v)
                + tpl.phases[j]
            )
            img += wave[..., None] * tpl.chan_mix[j][None, None, :]
        for j in range(tpl.blobs.shape[0]):
            cx, cy, sig, amp, _ = tpl.blobs[j]
            g = amp * np.exp(-(((xs - cx) ** 2 + (ys - cy) ** 2) / (2 * sig**2)))
            img += g[..., None] * tpl.blob_chan[j][None, None, :]
        img += rng.normal(0, noise, size=img.shape).astype(np.float32)
        # Per-sample contrast/brightness jitter.
        img = img * rng.uniform(0.8, 1.2) + rng.uniform(-0.2, 0.2)
        out[i] = img
    # Normalize into roughly [-1, 1].
    out = np.tanh(out * 0.6)
    return out


def generate(
    n_train: int = 8000,
    n_eval: int = 1024,
    seed: int = 7,
    noise: float = 1.6,
):
    """Return (x_train, y_train, x_eval, y_eval), deterministic in seed."""
    rng = np.random.default_rng(seed)
    templates = _make_templates(rng)
    per_tr = n_train // NUM_CLASSES
    per_ev = n_eval // NUM_CLASSES
    xs_tr, ys_tr, xs_ev, ys_ev = [], [], [], []
    for c, tpl in enumerate(templates):
        xs_tr.append(_render(tpl, rng, per_tr, noise))
        ys_tr.append(np.full(per_tr, c, dtype=np.int32))
        xs_ev.append(_render(tpl, rng, per_ev, noise))
        ys_ev.append(np.full(per_ev, c, dtype=np.int32))
    x_tr = np.concatenate(xs_tr)
    y_tr = np.concatenate(ys_tr)
    x_ev = np.concatenate(xs_ev)
    y_ev = np.concatenate(ys_ev)
    # Shuffle train split (eval order is irrelevant but shuffle anyway so
    # any batch is class-balanced on both sides).
    p = rng.permutation(len(x_tr))
    x_tr, y_tr = x_tr[p], y_tr[p]
    p = rng.permutation(len(x_ev))
    x_ev, y_ev = x_ev[p], y_ev[p]
    return x_tr, y_tr, x_ev, y_ev


def cached(cache_dir: str, **kw):
    """Generate-or-load: caches the dataset as an .npz under cache_dir."""
    os.makedirs(cache_dir, exist_ok=True)
    tag = "synth_{n_train}_{n_eval}_{seed}_n{noise}".format(
        n_train=kw.get("n_train", 8000),
        n_eval=kw.get("n_eval", 1024),
        seed=kw.get("seed", 7),
        noise=kw.get("noise", 1.6),
    )
    path = os.path.join(cache_dir, tag + ".npz")
    if os.path.exists(path):
        z = np.load(path)
        return z["x_tr"], z["y_tr"], z["x_ev"], z["y_ev"]
    x_tr, y_tr, x_ev, y_ev = generate(**kw)
    np.savez_compressed(path, x_tr=x_tr, y_tr=y_tr, x_ev=x_ev, y_ev=y_ev)
    return x_tr, y_tr, x_ev, y_ev


def write_eval_bin(path: str, x_ev: np.ndarray, y_ev: np.ndarray) -> None:
    """Serialize the eval split for the rust side.

    Layout (little-endian): u32 N, u32 D, f32[N*D] images, u8[N] labels.
    """
    n = x_ev.shape[0]
    flat = x_ev.reshape(n, -1).astype("<f4")
    d = flat.shape[1]
    with open(path, "wb") as f:
        f.write(struct.pack("<II", n, d))
        f.write(flat.tobytes())
        f.write(y_ev.astype(np.uint8).tobytes())
