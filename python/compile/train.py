"""Float32 pretraining + shared train/eval machinery.

Standard SGD with momentum on softmax cross-entropy. The same step
factory serves pretraining (wq=None) and WOT/QAT (wq=fake-quant variants)
so the two phases differ only in the weight transform and the throttling
hook — exactly the QATT structure of paper section 4.1.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize
from .models.common import ModelDef, Params


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_loss(
    model: ModelDef,
    wq: Optional[Callable],
    act: Optional[Callable],
    weight_decay: float,
):
    protected = set(model.protected_names())

    def loss_fn(params: Params, x, y):
        logits, upd = model.apply(params, x, train=True, wq=wq, act=act)
        loss = cross_entropy(logits, y)
        if weight_decay > 0.0:
            # The paper's lambda * sum_l ||W_l^q||_F^2 over protected
            # (quantized) weights; with STE the gradient passes through.
            reg = sum(
                jnp.sum(jnp.square(wq(params[n]) if wq else params[n]))
                for n in protected
            )
            loss = loss + weight_decay * reg
        return loss, upd

    return loss_fn


def make_step(
    model: ModelDef,
    lr: float,
    momentum: float,
    wq: Optional[Callable] = None,
    act: Optional[Callable] = None,
    weight_decay: float = 0.0,
):
    """SGD+momentum step. BN running stats (zero-gradient params) are
    overwritten from the forward pass's `updates` after the step."""
    loss_fn = make_loss(model, wq, act, weight_decay)

    @jax.jit
    def step(params: Params, mom: Params, x, y):
        (loss, upd), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y)
        new_mom = jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m, params, new_mom)
        new_params.update(upd)
        return new_params, new_mom, loss

    return step


def zeros_like_params(params: Params) -> Params:
    return jax.tree.map(jnp.zeros_like, params)


def batches(x: np.ndarray, y: np.ndarray, bs: int, steps: int, seed: int = 0):
    """Infinite shuffled batch stream, `steps` batches long."""
    rng = np.random.default_rng(seed)
    n = len(x)
    idx = rng.permutation(n)
    at = 0
    for _ in range(steps):
        if at + bs > n:
            idx = rng.permutation(n)
            at = 0
        sel = idx[at : at + bs]
        at += bs
        yield x[sel], y[sel]


def accuracy(
    model: ModelDef,
    params: Params,
    x: np.ndarray,
    y: np.ndarray,
    bs: int = 256,
    wq: Optional[Callable] = None,
    act: Optional[Callable] = None,
) -> float:
    @jax.jit
    def fwd(xb):
        logits, _ = model.apply(params, xb, train=False, wq=wq, act=act)
        return jnp.argmax(logits, axis=1)

    correct = 0
    for i in range(0, len(x), bs):
        xb, yb = x[i : i + bs], y[i : i + bs]
        if len(xb) < bs:  # pad the ragged tail so fwd stays one compilation
            padn = bs - len(xb)
            xb = np.concatenate([xb, np.zeros((padn,) + xb.shape[1:], xb.dtype)])
            pred = np.asarray(fwd(jnp.asarray(xb)))[: len(yb)]
        else:
            pred = np.asarray(fwd(jnp.asarray(xb)))
        correct += int((pred == yb).sum())
    return correct / len(y)


def pretrain(
    model: ModelDef,
    data,
    steps: int,
    bs: int,
    lr: float,
    momentum: float,
    seed: int = 3,
) -> Tuple[Params, float]:
    """Train float32 from scratch; returns (params, eval_accuracy)."""
    x_tr, y_tr, x_ev, y_ev = data
    params = model.init(jax.random.PRNGKey(seed))
    mom = zeros_like_params(params)
    step = make_step(model, lr, momentum, weight_decay=1e-4)
    for xb, yb in batches(x_tr, y_tr, bs, steps, seed):
        params, mom, loss = step(params, mom, jnp.asarray(xb), jnp.asarray(yb))
    acc = accuracy(model, params, x_ev, y_ev)
    return params, acc


def int8_accuracy(model: ModelDef, params: Params, x_ev, y_ev) -> float:
    """Accuracy with per-layer symmetric int8 fake-quant weights."""
    return accuracy(model, params, x_ev, y_ev, wq=quantize.fake_quant)
