"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref.py)."""

from .conv2d import conv2d_pallas
from .matmul import matmul, mxu_utilization, vmem_footprint_bytes
from .quant import fake_quant_pallas
from .throttle import throttle_pallas
