"""L1 Pallas kernel: symmetric range-based fake-quantization (paper Eq. 1).

Elementwise quantize->dequantize on the int8 grid. The scale is computed
by the caller (it is a reduction over the whole tensor, which belongs in
the surrounding HLO, not the tile kernel) and passed as a (1, 1) array.

Oracle: kernels/ref.py::fake_quant_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0
QMIN = -128.0


def _fq_kernel(x_ref, s_ref, o_ref):
    s = s_ref[0, 0]
    q = jnp.clip(jnp.round(x_ref[...] / s), QMIN, QMAX)
    o_ref[...] = q * s


@functools.partial(jax.jit, static_argnames=("block",))
def fake_quant_pallas(x: jnp.ndarray, scale: jnp.ndarray, block: int = 1024):
    """x: any shape f32; scale: scalar dequant step (max|x|/127)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    s = scale.reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _fq_kernel,
        grid=(flat.shape[0],),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, s)
    return out.reshape(-1)[:n].reshape(x.shape)
