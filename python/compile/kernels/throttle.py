"""L1 Pallas kernel: WOT throttling (paper section 4.1, QATT step 2).

Operates on int8-grid values (carried as f32): viewing the flat weight
vector as rows of 8 (one 64-bit memory block per row), clamp positions
0..6 into [-64, 63]; position 7 is the free byte allowed to stay large.

Oracle: quantize.throttle_q / kernels/ref.py::throttle_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SMALL_LO = -64.0
SMALL_HI = 63.0
BLOCK = 8


def _throttle_kernel(q_ref, o_ref):
    q = q_ref[...]  # (rows, 8)
    pos = jax.lax.broadcasted_iota(jnp.int32, q.shape, dimension=1)
    clamped = jnp.clip(q, SMALL_LO, SMALL_HI)
    o_ref[...] = jnp.where(pos < BLOCK - 1, clamped, q)


@functools.partial(jax.jit, static_argnames=("rows_per_step",))
def throttle_pallas(q: jnp.ndarray, rows_per_step: int = 512) -> jnp.ndarray:
    """q: flat f32 vector of int8-grid values, len % 8 == 0."""
    assert q.ndim == 1 and q.shape[0] % BLOCK == 0, q.shape
    rows = q.reshape(-1, BLOCK)
    n = rows.shape[0]
    pad = (-n) % rows_per_step
    rowsp = jnp.pad(rows, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _throttle_kernel,
        grid=(rowsp.shape[0] // rows_per_step,),
        in_specs=[pl.BlockSpec((rows_per_step, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_per_step, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(rowsp.shape, jnp.float32),
        interpret=True,
    )(rowsp)
    return out[:n].reshape(-1)
