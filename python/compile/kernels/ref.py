"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These are the ground truth: pytest (with hypothesis sweeps over shapes)
asserts each Pallas kernel matches its oracle to float32 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QMAX = 127.0
QMIN = -128.0
SMALL_LO = -64.0
SMALL_HI = 63.0
BLOCK = 8


def matmul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def fake_quant_ref(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(x / scale), QMIN, QMAX) * scale


def throttle_ref(q: jnp.ndarray) -> jnp.ndarray:
    rows = q.reshape(-1, BLOCK)
    pos = jnp.arange(BLOCK)
    clamped = jnp.clip(rows, SMALL_LO, SMALL_HI)
    return jnp.where(pos[None, :] < BLOCK - 1, clamped, rows).reshape(-1)
