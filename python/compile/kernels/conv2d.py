"""L1 Pallas kernel: quantized conv2d as im2col + blocked GEMM.

The paper's inference substrate is a quantized CNN; its compute hot-spot
is convolution. On TPU the canonical mapping is im2col (patch extraction,
a layout transform XLA fuses into the surrounding HLO) feeding the MXU
with a GEMM — which is the Pallas kernel (kernels/matmul.py). The GEMM
shapes are (B*H*W, KH*KW*Cin) x (KH*KW*Cin, Cout).

Oracle: kernels/ref.py::conv2d_ref (lax.conv_general_dilated).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


def _im2col(x: jnp.ndarray, kh: int, kw: int, stride: int) -> jnp.ndarray:
    """NHWC -> (B*OH*OW, KH*KW*C) patches, SAME padding.

    Implemented with conv_general_dilated_patches so the exported HLO
    keeps a single fusible gather; the channel-major patch order it emits
    (C outer, then KH, KW) is matched in the weight reshape below.
    """
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, OH, OW, C*KH*KW) with C slowest
    oh, ow = patches.shape[1], patches.shape[2]
    return patches.reshape(b * oh * ow, c * kh * kw), (b, oh, ow)


def conv2d_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int = 1,
    bm: int = 64,
    bn: int = 64,
    bk: int = 64,
) -> jnp.ndarray:
    """SAME conv, NHWC x HWIO -> NHWC, inner GEMM in Pallas."""
    kh, kw, cin, cout = w.shape
    cols, (b, oh, ow) = _im2col(x, kh, kw, stride)
    # Match the patch order (C, KH, KW): HWIO -> (C*KH*KW, O).
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    out = matmul(cols, wmat, bm=bm, bn=bn, bk=bk)
    return out.reshape(b, oh, ow, cout)
