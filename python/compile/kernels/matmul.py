"""L1 Pallas kernel: MXU-style blocked matmul.

The inference hot-spot of a quantized CNN is GEMM (convs run as im2col
GEMM, the classifier head is GEMM). This kernel expresses the TPU
mapping of that hot-spot: a (bm, bn) output tile held in VMEM scratch,
a K-loop as the innermost grid dimension accumulating partial products
(`preferred_element_type=f32` targets the MXU's f32 accumulators), and
BlockSpecs that describe the HBM->VMEM schedule.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (semantically identical;
DESIGN.md section 8 covers how TPU performance is estimated instead).

Correctness oracle: kernels/ref.py::matmul_ref (pure jnp), checked by
python/tests/test_kernels.py under a hypothesis shape sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    """Grid = (M/bm, N/bn, K/bk); K innermost so the accumulator tile
    stays resident in VMEM across the K-loop."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jnp.ndarray, y: jnp.ndarray, bm: int = 64, bn: int = 64, bk: int = 64
) -> jnp.ndarray:
    """x: (M, K) f32 @ y: (K, N) f32 -> (M, N) f32 via the Pallas kernel.

    Inputs are zero-padded up to block multiples and the result sliced
    back. Block defaults favour VMEM residency at our model sizes and are
    swept in the perf pass (EXPERIMENTS.md §Perf).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    yp = _pad_to(_pad_to(y, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    nm, nn, nk = mp // bm, np_ // bn, kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def vmem_footprint_bytes(bm: int, bn: int, bk: int) -> int:
    """Static VMEM usage of one grid step: x tile + y tile + acc tile.

    Used by the perf pass to pick block shapes under the ~16 MiB/core
    VMEM budget (DESIGN.md section 8: structural TPU estimates).
    """
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding)."""
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    return (m * n * k) / float(mp * np_ * kp)
