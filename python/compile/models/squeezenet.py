"""SqueezeNet scaled for 32x32 inputs (fire modules, 1/4-width).

Fire(s, e): 1x1 squeeze to s channels, then parallel 1x1 and 3x3 expands
to e channels each, concatenated. Classifier is the SqueezeNet-style
final 1x1 conv + global average pool (no FC).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, Params, avgpool_global, he_conv, maxpool

FIRES = [(8, 16), (8, 16), (16, 32), (16, 32), (24, 48), (24, 48)]
POOL_AFTER = {1, 3}  # maxpool after these fire indices


class SqueezeNetS(ModelDef):
    name = "squeezenet_s"

    def __init__(self, num_classes: int = 10):
        super().__init__(num_classes)
        self.tensors.append(("stem.w", (3, 3, 3, 16)))
        cin = 16
        for i, (s, e) in enumerate(FIRES):
            self.tensors.append((f"f{i}.sq.w", (1, 1, cin, s)))
            self.tensors.append((f"f{i}.e1.w", (1, 1, s, e)))
            self.tensors.append((f"f{i}.e3.w", (3, 3, s, e)))
            cin = 2 * e
        # Final classifier conv: 1x1 to num_classes, then GAP.
        self.tensors.append(("head.w", (1, 1, cin, num_classes)))

    def init(self, key) -> Params:
        params: Params = {}
        keys = iter(jax.random.split(key, len(self.tensors)))
        for name, shape in self.tensors:
            params[name] = he_conv(next(keys), *shape)
            params[name[:-2] + ".b"] = jnp.zeros((shape[-1],), jnp.float32)
        return params

    def _forward(self, params, x, wq, act, train, conv, dense_fn, updates):
        def c(base, x, **kw):
            return conv(x, wq(params[base + ".w"]), **kw) + params[base + ".b"]

        x = act(jax.nn.relu(c("stem", x)))
        x = maxpool(x)
        for i in range(len(FIRES)):
            s = act(jax.nn.relu(c(f"f{i}.sq", x)))
            e1 = act(jax.nn.relu(c(f"f{i}.e1", s)))
            e3 = act(jax.nn.relu(c(f"f{i}.e3", s)))
            x = jnp.concatenate([e1, e3], axis=-1)
            if i in POOL_AFTER:
                x = maxpool(x)
        x = c("head", x)
        return avgpool_global(x)
