"""AlexNet scaled for 32x32 inputs (5 convs + 2 FC, 3x3 kernels).

The original's 11x11/5x5 front-end makes no sense at 32x32; the standard
CIFAR adaptation (all 3x3, three 2x2 pools) is used, widths ~1/8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, Params, he_conv, he_dense, maxpool

CONVS = [16, 24, 32, 32, 24]  # conv widths; pools after conv0, conv1, conv4
FC_WIDTH = 128


class AlexNetS(ModelDef):
    name = "alexnet_s"

    def __init__(self, num_classes: int = 10):
        super().__init__(num_classes)
        cin = 3
        for i, w in enumerate(CONVS):
            self.tensors.append((f"conv{i}.w", (3, 3, cin, w)))
            cin = w
        # Three pools: 32 -> 16 -> 8 -> 4; final map 4x4x24 = 384.
        self.tensors.append(("fc0.w", (4 * 4 * CONVS[-1], FC_WIDTH)))
        self.tensors.append(("fc1.w", (FC_WIDTH, num_classes)))

    def init(self, key) -> Params:
        params: Params = {}
        keys = iter(jax.random.split(key, len(self.tensors)))
        for name, shape in self.tensors:
            if name.startswith("conv"):
                params[name] = he_conv(next(keys), *shape)
            else:
                params[name] = he_dense(next(keys), *shape)
            params[name[:-2] + ".b"] = jnp.zeros((shape[-1],), jnp.float32)
        return params

    def _forward(self, params, x, wq, act, train, conv, dense_fn, updates):
        for i in range(len(CONVS)):
            x = conv(x, wq(params[f"conv{i}.w"])) + params[f"conv{i}.b"]
            x = act(jax.nn.relu(x))
            if i in (0, 1, 4):
                x = maxpool(x)
        x = x.reshape(x.shape[0], -1)
        x = act(jax.nn.relu(dense_fn(x, wq(params["fc0.w"])) + params["fc0.b"]))
        return dense_fn(x, wq(params["fc1.w"])) + params["fc1.b"]
