"""VGG16-family scaled for 32x32 inputs (paper models: VGG16, VGG16_bn).

Original VGG16 conv plan 2x64, 2x128, 3x256, 3x512, 3x512 is scaled by
1/8 (widths stay multiples of 8 so every protected tensor tiles into
whole 64-bit blocks); the 4096-wide FC stack becomes 128.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (
    ModelDef,
    Params,
    avgpool_global,
    bn_apply,
    bn_init,
    he_conv,
    he_dense,
    maxpool,
)

# (layer plan) 'M' = maxpool 2x2.
PLAN = [8, 8, "M", 16, 16, "M", 32, 32, 32, "M", 64, 64, 64, "M", 64, 64, 64, "M"]
FC_WIDTH = 128


class VGG16S(ModelDef):
    name = "vgg16_s"
    use_bn = False

    def __init__(self, num_classes: int = 10):
        super().__init__(num_classes)
        cin = 3
        i = 0
        for v in PLAN:
            if v == "M":
                continue
            self.tensors.append((f"conv{i}.w", (3, 3, cin, v)))
            cin = v
            i += 1
        # After five 2x2 pools on 32x32 the map is 1x1 x 64.
        self.tensors.append(("fc0.w", (64, FC_WIDTH)))
        self.tensors.append(("fc1.w", (FC_WIDTH, FC_WIDTH)))
        self.tensors.append(("fc2.w", (FC_WIDTH, num_classes)))

    def init(self, key) -> Params:
        params: Params = {}
        keys = jax.random.split(key, len(self.tensors))
        i = 0
        for (name, shape), k in zip(self.tensors, keys):
            if name.startswith("conv"):
                params[name] = he_conv(k, *shape)
                params[name[:-2] + ".b"] = jnp.zeros((shape[-1],), jnp.float32)
                if self.use_bn:
                    bn_init(params, name[:-2] + ".bn", shape[-1])
            else:
                params[name] = he_dense(k, *shape)
                params[name[:-2] + ".b"] = jnp.zeros((shape[-1],), jnp.float32)
            i += 1
        return params

    def _forward(self, params, x, wq, act, train, conv, dense_fn, updates):
        i = 0
        for v in PLAN:
            if v == "M":
                x = maxpool(x)
                continue
            name = f"conv{i}"
            x = conv(x, wq(params[name + ".w"])) + params[name + ".b"]
            if self.use_bn:
                x = bn_apply(params, name + ".bn", x, train, updates)
            x = act(jax.nn.relu(x))
            i += 1
        x = x.reshape(x.shape[0], -1)  # 1x1x64 -> 64
        x = act(jax.nn.relu(dense_fn(x, wq(params["fc0.w"])) + params["fc0.b"]))
        x = act(jax.nn.relu(dense_fn(x, wq(params["fc1.w"])) + params["fc1.b"]))
        return dense_fn(x, wq(params["fc2.w"])) + params["fc2.b"]


class VGG16BNS(VGG16S):
    name = "vgg16bn_s"
    use_bn = True
