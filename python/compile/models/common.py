"""Functional CNN building blocks shared by the model zoo.

Design:
  * A model is a `ModelDef` with `init(key) -> params` and
    `apply(params, x, ...) -> (logits, bn_updates)`.
  * `params` is a flat dict name -> array. Conv / dense *weights* (names
    ending in ".w") are the paper's protected tensors: they are the ones
    quantized to int8, laid out in the 64-bit-block memory and covered by
    in-place ECC. Biases / batch-norm parameters are auxiliary (the paper
    protects weights; biases are 32-bit and conventionally protected) and
    are baked into the exported HLO as constants.
  * `apply` takes injection points so the same definition serves float
    training (wq=None), QAT/WOT (wq=fake-quant variants), int8 evaluation
    and the AOT export with either the plain-jnp ops or the L1 Pallas
    kernels (conv=/dense=).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jnp.ndarray]


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """SAME conv, NHWC activations, HWIO weights."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return x @ w


def maxpool(x: jnp.ndarray, k: int = 2, s: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID"
    )


def avgpool_global(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def he_conv(key, kh, kw, cin, cout):
    std = np.sqrt(2.0 / (kh * kw * cin))
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def he_dense(key, cin, cout):
    std = np.sqrt(2.0 / cin)
    return jax.random.normal(key, (cin, cout), jnp.float32) * std


def bn_apply(
    params: Params,
    name: str,
    x: jnp.ndarray,
    train: bool,
    updates: Params,
    momentum: float = 0.9,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """Standard batchnorm over NHWC channel axis with running stats.

    In train mode, batch statistics normalize and the EMA-updated running
    stats are written into `updates` (the caller merges them back).
    """
    g, b = params[name + ".gamma"], params[name + ".beta"]
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        updates[name + ".mu"] = momentum * params[name + ".mu"] + (1 - momentum) * mu
        updates[name + ".var"] = (
            momentum * params[name + ".var"] + (1 - momentum) * var
        )
    else:
        mu, var = params[name + ".mu"], params[name + ".var"]
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def bn_init(params: Params, name: str, c: int) -> None:
    params[name + ".gamma"] = jnp.ones((c,), jnp.float32)
    params[name + ".beta"] = jnp.zeros((c,), jnp.float32)
    params[name + ".mu"] = jnp.zeros((c,), jnp.float32)
    params[name + ".var"] = jnp.ones((c,), jnp.float32)


class ModelDef:
    """Base class: subclasses fill `tensors` (ordered protected weights)
    and implement `_forward`."""

    name: str = "base"

    def __init__(self, num_classes: int = 10):
        self.num_classes = num_classes
        # Ordered (name, shape) of protected tensors; populated by subclass.
        self.tensors: List[Tuple[str, Tuple[int, ...]]] = []

    # -- protected-tensor bookkeeping ---------------------------------
    def protected_names(self) -> List[str]:
        return [n for n, _ in self.tensors]

    def protected_shapes(self) -> Dict[str, Tuple[int, ...]]:
        return dict(self.tensors)

    def num_weights(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.tensors)

    # -- to be provided by subclass -----------------------------------
    def init(self, key) -> Params:
        raise NotImplementedError

    def _forward(
        self,
        params: Params,
        x: jnp.ndarray,
        wq: Callable,
        act: Callable,
        train: bool,
        conv: Callable,
        dense_fn: Callable,
        updates: Params,
    ) -> jnp.ndarray:
        raise NotImplementedError

    # -- public entry ---------------------------------------------------
    def apply(
        self,
        params: Params,
        x: jnp.ndarray,
        *,
        wq: Optional[Callable] = None,
        act: Optional[Callable] = None,
        train: bool = False,
        conv: Callable = conv2d,
        dense_fn: Callable = dense,
    ) -> Tuple[jnp.ndarray, Params]:
        """Returns (logits, bn_updates). wq transforms each protected
        weight before use (fake-quant etc.); act transforms activations
        after each nonlinearity (activation quantization)."""
        wq = wq or (lambda w: w)
        act = act or (lambda a: a)
        updates: Params = {}
        logits = self._forward(params, x, wq, act, train, conv, dense_fn, updates)
        return logits, updates
