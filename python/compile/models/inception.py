"""Inception-style network scaled for 32x32 inputs (Inception_V3 stand-in).

Two inception blocks with the canonical four branches (1x1, 1x1->3x3,
1x1->3x3->3x3 as the 5x5 factorization, pool->1x1), pooling between,
global average pool + FC head. Widths multiples of 8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, Params, avgpool_global, he_conv, he_dense, maxpool


def _pool3s1(x: jnp.ndarray) -> jnp.ndarray:
    """3x3 stride-1 SAME maxpool (the inception pool branch)."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 1, 1, 1), "SAME"
    )


def _inc_tensors(prefix: str, cin: int, b1: int, b3r: int, b3: int, b5r: int, b5: int, bp: int):
    """Tensor plan of one inception block; returns (tensors, cout)."""
    t = [
        (f"{prefix}.b1.w", (1, 1, cin, b1)),
        (f"{prefix}.b3r.w", (1, 1, cin, b3r)),
        (f"{prefix}.b3.w", (3, 3, b3r, b3)),
        (f"{prefix}.b5r.w", (1, 1, cin, b5r)),
        (f"{prefix}.b5a.w", (3, 3, b5r, b5)),
        (f"{prefix}.b5b.w", (3, 3, b5, b5)),
        (f"{prefix}.bp.w", (1, 1, cin, bp)),
    ]
    return t, b1 + b3 + b5 + bp


BLOCKS = [
    ("incA", 16, 8, 16, 8, 8, 8),  # cout = 16+16+8+8 = 48
    ("incB", 24, 16, 32, 8, 16, 16),  # cout = 24+32+16+16 = 88
]


class InceptionS(ModelDef):
    name = "inception_s"

    def __init__(self, num_classes: int = 10):
        super().__init__(num_classes)
        self.tensors.append(("stem.w", (3, 3, 3, 16)))
        cin = 16
        for name, *cfg in BLOCKS:
            t, cin = _inc_tensors(name, cin, *cfg)
            self.tensors.extend(t)
        self.tensors.append(("fc.w", (cin, num_classes)))
        self._cout = cin

    def init(self, key) -> Params:
        params: Params = {}
        keys = iter(jax.random.split(key, len(self.tensors)))
        for name, shape in self.tensors:
            if name == "fc.w":
                params[name] = he_dense(next(keys), *shape)
            else:
                params[name] = he_conv(next(keys), *shape)
            params[name[:-2] + ".b"] = jnp.zeros((shape[-1],), jnp.float32)
        return params

    def _forward(self, params, x, wq, act, train, conv, dense_fn, updates):
        def c(base, x):
            return act(jax.nn.relu(conv(x, wq(params[base + ".w"])) + params[base + ".b"]))

        x = c("stem", x)
        x = maxpool(x)  # 16x16
        for name, *_ in BLOCKS:
            b1 = c(f"{name}.b1", x)
            b3 = c(f"{name}.b3", c(f"{name}.b3r", x))
            b5 = c(f"{name}.b5b", c(f"{name}.b5a", c(f"{name}.b5r", x)))
            bp = c(f"{name}.bp", _pool3s1(x))
            x = jnp.concatenate([b1, b3, b5, bp], axis=-1)
            x = maxpool(x)
        x = avgpool_global(x)
        return dense_fn(x, wq(params["fc.w"])) + params["fc.b"]
