"""Model zoo registry.

Six scaled-down counterparts of the paper's Table-1 models (DESIGN.md
section 2 documents the scaling substitution). The three fault-injection
models of Table 2 are vgg16_s, resnet18_s, squeezenet_s.
"""

from .alexnet import AlexNetS
from .common import ModelDef, conv2d, dense
from .inception import InceptionS
from .resnet import ResNet18S
from .squeezenet import SqueezeNetS
from .vgg import VGG16BNS, VGG16S

REGISTRY = {
    m.name: m
    for m in (VGG16S, VGG16BNS, ResNet18S, SqueezeNetS, AlexNetS, InceptionS)
}

# Order used everywhere (Table 1 columns, artifact export).
ALL_MODELS = ["alexnet_s", "vgg16_s", "vgg16bn_s", "inception_s", "resnet18_s", "squeezenet_s"]
# Table 2 / fault-injection subset (paper: VGG16, ResNet18, SqueezeNet).
FAULT_MODELS = ["vgg16_s", "resnet18_s", "squeezenet_s"]


def get(name: str, num_classes: int = 10) -> ModelDef:
    return REGISTRY[name](num_classes)
