"""ResNet18 scaled for 32x32 inputs (basic blocks, 4 stages x 2 blocks).

Widths 8/16/32/64 (1/8 of the original 64/128/256/512); stem is the
CIFAR-style single 3x3 conv. Downsampling shortcuts are 1x1 convs (they
are protected tensors too — they live in weight memory like any other).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (
    ModelDef,
    Params,
    avgpool_global,
    bn_apply,
    bn_init,
    he_conv,
    he_dense,
)

STAGES = [(8, 1), (16, 2), (32, 2), (64, 2)]  # (width, first-block stride)
BLOCKS = 2


class ResNet18S(ModelDef):
    name = "resnet18_s"

    def __init__(self, num_classes: int = 10):
        super().__init__(num_classes)
        self.tensors.append(("stem.w", (3, 3, 3, 8)))
        cin = 8
        for si, (w, _) in enumerate(STAGES):
            for bi in range(BLOCKS):
                p = f"s{si}b{bi}"
                self.tensors.append((f"{p}.c1.w", (3, 3, cin, w)))
                self.tensors.append((f"{p}.c2.w", (3, 3, w, w)))
                if cin != w:
                    self.tensors.append((f"{p}.ds.w", (1, 1, cin, w)))
                cin = w
        self.tensors.append(("fc.w", (64, num_classes)))

    def init(self, key) -> Params:
        params: Params = {}
        keys = iter(jax.random.split(key, len(self.tensors) + 8))
        for name, shape in self.tensors:
            if name == "fc.w":
                params[name] = he_dense(next(keys), *shape)
                params["fc.b"] = jnp.zeros((shape[-1],), jnp.float32)
            else:
                params[name] = he_conv(next(keys), *shape)
                bn_init(params, name[:-2] + ".bn", shape[-1])
        return params

    def _conv_bn(self, params, base, x, wq, train, conv, updates, stride=1):
        x = conv(x, wq(params[base + ".w"]), stride)
        return bn_apply(params, base + ".bn", x, train, updates)

    def _forward(self, params, x, wq, act, train, conv, dense_fn, updates):
        x = act(jax.nn.relu(self._conv_bn(params, "stem", x, wq, train, conv, updates)))
        cin = 8
        for si, (w, stride0) in enumerate(STAGES):
            for bi in range(BLOCKS):
                p = f"s{si}b{bi}"
                stride = stride0 if bi == 0 else 1
                h = act(
                    jax.nn.relu(
                        self._conv_bn(params, p + ".c1", x, wq, train, conv, updates, stride)
                    )
                )
                h = self._conv_bn(params, p + ".c2", h, wq, train, conv, updates)
                if cin != w:
                    sc = self._conv_bn(params, p + ".ds", x, wq, train, conv, updates, stride)
                elif stride != 1:
                    sc = x[:, ::stride, ::stride, :]
                else:
                    sc = x
                x = act(jax.nn.relu(h + sc))
                cin = w
        x = avgpool_global(x)
        return dense_fn(x, wq(params["fc.w"])) + params["fc.b"]
