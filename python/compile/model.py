"""L2: the exported quantized-inference graph.

Signature of every exported executable (one per (model, batch, variant)):

    infer(weights_flat f32[P], images f32[B, 3072]) -> (logits f32[B, 10],)

`weights_flat` is the *dequantized* protected weight buffer in canonical
layout (tensors order, C-order ravel, per-layer offsets from the
manifest): the rust coordinator owns the int8 bytes, runs the protection
decode (in-place ECC etc.), dequantizes with the per-layer scales and
feeds one flat buffer per scrub epoch. Biases / batch-norm parameters are
baked into the HLO as constants (the paper protects weights only).

Variants: "fast" uses plain jnp conv/dense; "pallas" routes every conv
and dense through the L1 Pallas kernels (interpret=True), lowering them
into the same HLO. Both must agree numerically (pytest + rust e2e test).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .data import IMG_DIM, IMG_SIZE
from .kernels import conv2d_pallas, matmul
from .models.common import ModelDef, Params, conv2d, dense


def layer_table(model: ModelDef) -> List[Dict]:
    """Manifest layer records: name, shape, element offset/size.

    Every protected tensor's size is a multiple of 8 (enforced), so
    64-bit blocks never straddle layers and offsets are block-aligned.
    """
    table = []
    off = 0
    for name, shape in model.tensors:
        size = 1
        for d in shape:
            size *= d
        assert size % 8 == 0, f"{name} size {size} not block-aligned"
        table.append({"name": name, "shape": list(shape), "offset": off, "size": size})
        off += size
    return table


def split_flat(wflat: jnp.ndarray, table: List[Dict]) -> Dict[str, jnp.ndarray]:
    out = {}
    for rec in table:
        seg = jax.lax.dynamic_slice(wflat, (rec["offset"],), (rec["size"],))
        out[rec["name"]] = seg.reshape(rec["shape"])
    return out


def aux_params(model: ModelDef, params: Params) -> Params:
    """Everything that is NOT a protected tensor (biases, BN) — baked."""
    protected = set(model.protected_names())
    return {k: v for k, v in params.items() if k not in protected}


def make_infer(
    model: ModelDef,
    params: Params,
    batch: int,
    use_pallas: bool = False,
) -> Callable:
    aux = aux_params(model, params)
    table = layer_table(model)
    conv = conv2d_pallas if use_pallas else conv2d
    dense_fn = (lambda x, w: matmul(x, w)) if use_pallas else dense

    def infer(wflat: jnp.ndarray, images: jnp.ndarray):
        p = dict(aux)
        p.update(split_flat(wflat, table))
        x = images.reshape(batch, IMG_SIZE, IMG_SIZE, 3)
        logits, _ = model.apply(p, x, train=False, conv=conv, dense_fn=dense_fn)
        return (logits,)

    return infer


def lower_to_hlo_text(
    model: ModelDef, params: Params, batch: int, use_pallas: bool = False
) -> str:
    """jit -> lower -> stablehlo -> XlaComputation -> HLO *text*.

    Text is the interchange format: jax>=0.5 serialized protos carry
    64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    parser reassigns ids (see /opt/xla-example/README.md).
    """
    from jax._src.lib import xla_client as xc  # noqa: PLC0415

    infer = make_infer(model, params, batch, use_pallas)
    nw = model.num_weights()
    wspec = jax.ShapeDtypeStruct((nw,), jnp.float32)
    xspec = jax.ShapeDtypeStruct((batch, IMG_DIM), jnp.float32)
    lowered = jax.jit(infer).lower(wspec, xspec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is LOAD-BEARING: the default text printer
    # elides big constants as `constant({...})`, which the xla_extension
    # 0.5.1 text parser silently reads as ZEROS — baked biases/batch-norm
    # tensors would vanish on the rust side (logits go constant for BN
    # models). Non-negotiable for the AOT interchange.
    return comp.as_hlo_text(print_large_constants=True)


def dequant_flat(qflat, table: List[Dict], scales: Dict[str, float]) -> jnp.ndarray:
    """Reference dequantizer (mirrors what rust does): int8 buffer ->
    flat f32 with per-layer scales. Used by tests to validate the rust
    path and the exported graph end-to-end."""
    import numpy as np  # noqa: PLC0415

    out = np.zeros(qflat.shape[0], dtype=np.float32)
    for rec in table:
        a, b = rec["offset"], rec["offset"] + rec["size"]
        out[a:b] = qflat[a:b].astype(np.float32) * scales[rec["name"]]
    return jnp.asarray(out)
