"""WOT: Weight-distribution Oriented Training (paper section 4.1, QATT).

Per batch:
  1. QAT — forward with per-layer symmetric fake-quant weights (STE),
     loss = cross-entropy + lambda * ||W_q||_F^2, SGD+momentum update of
     the float32 masters.
  2. Throttling — quantize the masters, clamp positions 0..6 of every
     8-value block to [-64, 63], and write the clamped values back into
     the float32 masters (only where clamping changed a value, so
     sub-quantization-step gradient progress on small weights survives).

Quantization scales are *frozen* at their pre-WOT calibration values
(see quantize.fake_quant_fixed for why dynamic rescaling cascades); the
frozen scales are exactly what the manifest records for the rust-side
dequantizer, so training, export and serving all share one int8 grid.

Logged (the paper's Fig. 3 / Fig. 4 series): the number of large values
in positions 0..6 *before* throttling, and eval accuracy before/after
throttling, every `log_every` steps.
"""

from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize, train
from .models.common import ModelDef, Params


def calibration_scales(params: Params, protected: List[str]) -> Dict[str, float]:
    """Per-layer frozen scales from the (pretrained) masters."""
    return {n: float(quantize.scale_of(params[n])) for n in protected}


@functools.partial(jax.jit, static_argnames=("scale",))
def _throttle_writeback(w: jnp.ndarray, scale: float):
    """Returns (new_w, n_large): throttled master weights + Fig-3 count."""
    q = quantize.quantize(w, scale)
    qt = quantize.throttle_q(q.reshape(-1)).reshape(q.shape)
    n_large = jnp.sum(qt != q)
    new_w = jnp.where(qt != q, quantize.dequantize(qt, scale), w)
    return new_w, n_large


def throttle_params(params: Params, scales: Dict[str, float]):
    """Throttle every protected tensor; returns (params, total_large)."""
    out = dict(params)
    total = 0
    for name, s in scales.items():
        neww, n = _throttle_writeback(params[name], s)
        out[name] = neww
        total += int(n)
    return out, total


def qat_view(params: Params, scales: Dict[str, float], throttled: bool = False) -> Params:
    """Masters -> params whose protected tensors are fake-quantized (STE)
    on the frozen grid; what the QAT forward pass and all evals see."""
    fq = quantize.throttled_fake_quant_fixed if throttled else quantize.fake_quant_fixed
    out = dict(params)
    for n, s in scales.items():
        out[n] = fq(params[n], s)
    return out


def make_qat_step(
    model: ModelDef,
    scales: Dict[str, float],
    lr: float,
    momentum: float,
    weight_decay: float,
):
    def loss_fn(params: Params, x, y):
        qp = qat_view(params, scales)
        logits, upd = model.apply(qp, x, train=True)
        loss = train.cross_entropy(logits, y)
        # lambda * sum ||W_q||_F^2 on the quantized view (paper Eq. 2).
        reg = sum(jnp.sum(jnp.square(qp[n])) for n in scales)
        return loss + weight_decay * reg, upd

    @jax.jit
    def step(params: Params, mom: Params, x, y):
        (loss, upd), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y)
        new_mom = jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m, params, new_mom)
        new_params.update(upd)
        return new_params, new_mom, loss

    return step


def eval_acc(model: ModelDef, params, scales, x, y, throttled: bool) -> float:
    return train.accuracy(model, qat_view(params, scales, throttled), x, y)


def quantized_weights_flat(
    params: Params, protected: List[str], scales: Dict[str, float]
) -> np.ndarray:
    """Concatenated int8 weights (frozen scales) in canonical layout —
    the exact bytes the rust memory bank stores. Hard-clamped so the WOT
    block constraint holds unconditionally."""
    chunks = []
    for name in protected:
        q = np.asarray(quantize.quantize(params[name], scales[name]))
        q = np.asarray(quantize.throttle_q(jnp.asarray(q.reshape(-1)))).reshape(q.shape)
        chunks.append(q.astype(np.int8).reshape(-1))
    return np.concatenate(chunks)


def wot_finetune(
    model: ModelDef,
    params: Params,
    data,
    steps: int,
    bs: int,
    lr: float,
    momentum: float,
    weight_decay: float,
    log_every: int = 25,
    seed: int = 11,
    eval_subset: int = 512,
):
    """Run QATT; returns (params, scales, log) where log carries the
    Fig-3/Fig-4 series and the final accuracies."""
    x_tr, y_tr, x_ev, y_ev = data
    protected = model.protected_names()
    scales = calibration_scales(params, protected)
    step = make_qat_step(model, scales, lr, momentum, weight_decay)
    mom = train.zeros_like_params(params)
    xs, ys = x_ev[:eval_subset], y_ev[:eval_subset]

    log: Dict[str, List[float]] = {
        "step": [],
        "n_large": [],
        "acc_before": [],
        "acc_after": [],
    }

    i = 0
    for xb, yb in train.batches(x_tr, y_tr, bs, steps, seed):
        params, mom, _ = step(params, mom, jnp.asarray(xb), jnp.asarray(yb))
        before = params
        params, n_large = throttle_params(params, scales)
        if i % log_every == 0 or i == steps - 1:
            log["step"].append(i)
            log["n_large"].append(n_large)
            log["acc_before"].append(eval_acc(model, before, scales, xs, ys, False))
            log["acc_after"].append(eval_acc(model, params, scales, xs, ys, True))
        i += 1

    # Final hard throttle (idempotent with frozen scales, but guarantees
    # the exported constraint at any step count).
    params, _ = throttle_params(params, scales)
    # The throttled view is the exact function of the exported int8
    # buffer, so rust-side accuracy matches this number.
    final_acc = eval_acc(model, params, scales, x_ev, y_ev, True)
    log["final_acc"] = final_acc
    return params, scales, log


def check_constraint(qflat: np.ndarray) -> int:
    """Number of WOT violations (large values at positions 0..6) in a flat
    int8 buffer — must be 0 after wot_finetune."""
    assert qflat.size % quantize.BLOCK == 0
    blocks = qflat.reshape(-1, quantize.BLOCK).astype(np.int32)
    large = (blocks < quantize.SMALL_LO) | (blocks > quantize.SMALL_HI)
    return int(large[:, : quantize.FREE_POS].sum())
